//! Checkpointing + single-process evaluation.
//!
//! Each stage writes its parameters in the exact manifest `.bin` layout, so
//! a checkpoint directory is a drop-in replacement for `artifacts/params/`.
//! `evaluate` runs the full forward chain + `loss_eval` artifact over
//! held-out synthetic batches — the validation-loss half of Fig. 5.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::Corpus;
use crate::runtime::{Manifest, Runtime, Tensor};

/// Write one stage's parameters as `<dir>/stage<i>.bin` (manifest layout).
pub fn save_stage(
    dir: &Path,
    stage: usize,
    manifest: &Manifest,
    params: &[Tensor],
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let specs = &manifest.stages[stage].params;
    if specs.len() != params.len() {
        bail!("stage {stage}: {} tensors vs {} specs", params.len(), specs.len());
    }
    let mut bytes = Vec::with_capacity(manifest.stages[stage].total_bytes);
    for (t, spec) in params.iter().zip(specs) {
        if t.shape != spec.shape {
            bail!("checkpoint shape mismatch for {}", spec.name);
        }
        for v in t.as_f32()? {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(dir.join(format!("stage{stage}.bin")), bytes)
        .with_context(|| format!("writing checkpoint stage {stage}"))?;
    Ok(())
}

/// Load a stage's parameters from a checkpoint directory (manifest layout).
pub fn load_stage(dir: &Path, stage: usize, manifest: &Manifest) -> Result<Vec<Tensor>> {
    let path = dir.join(format!("stage{stage}.bin"));
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let sp = &manifest.stages[stage];
    if bytes.len() != sp.total_bytes {
        bail!("{}: {} bytes, expected {}", path.display(), bytes.len(), sp.total_bytes);
    }
    Ok(sp
        .params
        .iter()
        .map(|p| {
            let data: Vec<f32> = bytes[p.offset..p.offset + p.numel * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Tensor::f32(data, p.shape.clone())
        })
        .collect())
}

/// Validation loss over `batches` held-out batches.
///
/// `checkpoint`: parameters to evaluate (None = the initial params shipped
/// with the artifacts). `structure_seed` must match the training corpus
/// (same language); `stream_seed` re-seeds the sampling so the batches are
/// held out.
pub fn evaluate(
    artifacts: &Path,
    checkpoint: Option<&Path>,
    batches: usize,
    structure_seed: u64,
    stream_seed: u64,
) -> Result<f32> {
    let mut rt = Runtime::open(artifacts)?;
    let m = rt.manifest.model.clone();
    let stages = m.stages;

    let mut params = Vec::with_capacity(stages);
    for s in 0..stages {
        params.push(match checkpoint {
            Some(dir) => load_stage(dir, s, &rt.manifest)?,
            None => rt.load_stage_params(s)?,
        });
    }

    let v = m.virtual_stages;
    let mut corpus = Corpus::new(m.vocab, structure_seed);
    corpus.reseed_stream(stream_seed);
    let mut total = 0.0f32;
    for _ in 0..batches {
        let (tokens, targets) = corpus.batch(m.micro_batch, m.seq);
        let mut x = Tensor::i32(tokens, vec![m.micro_batch, m.seq]);
        let mut aux = 0.0f32;
        // chain the virtual stages in ring order: chunk c of stage p−1
        // wraps around into chunk c+1 of stage 0
        for vs in 0..stages * v - 1 {
            let (s, c) = (vs % stages, vs / stages);
            let name = rt.manifest.chunks[s][c]
                .fwd
                .clone()
                .context("non-loss chunk missing fwd artifact")?;
            let exe = rt.load(&name)?;
            let range = rt.manifest.chunk_param_range(s, c);
            let mut inputs = params[s][range].to_vec();
            inputs.push(x);
            let out = exe.run(&inputs)?;
            x = out[0].clone();
            aux += out[1].item()?;
        }
        let exe = rt.load("loss_eval")?;
        let range = rt.manifest.chunk_param_range(stages - 1, v - 1);
        let mut inputs = params[stages - 1][range].to_vec();
        inputs.push(x);
        inputs.push(Tensor::i32(targets, vec![m.micro_batch, m.seq]));
        inputs.push(Tensor::scalar_f32(aux));
        total += exe.run(&inputs)?[0].item()?;
    }
    Ok(total / batches as f32)
}

#[cfg(test)]
mod tests {
    // round-trip layout logic is covered here; PJRT-dependent paths are
    // exercised by rust/tests/trainer_and_tp.rs::checkpoint_eval_improves.
    use super::*;
    use crate::runtime::manifest::{Manifest, ParamSpec, StageParams};
    use crate::runtime::manifest::ModelInfo;
    use std::collections::BTreeMap;

    fn fake_manifest() -> Manifest {
        Manifest {
            model: ModelInfo {
                config_name: "t".into(), vocab: 4, hidden: 2, layers: 1,
                experts: 1, seq: 2, micro_batch: 1, stages: 1,
                virtual_stages: 1, aux_coef: 0.0,
            },
            tp: 1,
            stages: vec![StageParams {
                bin: "params/stage0.bin".into(),
                total_bytes: 24,
                params: vec![
                    ParamSpec { name: "a".into(), shape: vec![2, 2], offset: 0, numel: 4 },
                    ParamSpec { name: "b".into(), shape: vec![2], offset: 16, numel: 2 },
                ],
            }],
            chunks: vec![vec![crate::runtime::manifest::ChunkSpec {
                fwd: None,
                bwd: "lossgrad".into(),
                params: 2,
            }]],
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ppmoe_ckpt_{}", std::process::id()));
        let m = fake_manifest();
        let params = vec![
            Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]),
            Tensor::f32(vec![5.0, 6.0], vec![2]),
        ];
        save_stage(&dir, 0, &m, &params).unwrap();
        let loaded = load_stage(&dir, 0, &m).unwrap();
        assert_eq!(loaded, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_rejects_wrong_shapes() {
        let dir = std::env::temp_dir().join(format!("ppmoe_ckpt2_{}", std::process::id()));
        let m = fake_manifest();
        let bad = vec![
            Tensor::f32(vec![1.0; 2], vec![2]), // wrong shape for "a"
            Tensor::f32(vec![5.0, 6.0], vec![2]),
        ];
        assert!(save_stage(&dir, 0, &m, &bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
