//! Checkpointing + single-process evaluation.
//!
//! Each stage writes its parameters in the exact manifest `.bin` layout, so
//! a checkpoint directory is a drop-in replacement for `artifacts/params/`.
//! Alongside the parameters, a checkpoint carries the **sharded optimizer
//! state** (`stage<i>.opt.bin`: per-chunk Adam moments + step counters,
//! [`save_optimizer`]) and a tiny `train_state.json` (completed optimizer
//! steps, [`save_train_state`]) so a resumed run replays the exact data
//! stream position — together they make resumption **bitwise** equal to an
//! uninterrupted run (rust/tests/trainer_and_tp.rs).
//! `evaluate` runs the full forward chain + `loss_eval` artifact over
//! held-out synthetic batches — the validation-loss half of Fig. 5.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::adam::ShardedAdam;
use crate::comm::collectives::segment;
use crate::data::Corpus;
use crate::runtime::{Manifest, ParamSpec, Runtime, Tensor};

/// Write `<dir>/<file>` atomically: bytes land under a temporary name and
/// are renamed into place, so a reader (or a crash) can never observe a
/// half-written file — rename within a directory is atomic on POSIX
/// filesystems. Every checkpoint file goes through here.
fn atomic_write(dir: &Path, file: &str, bytes: &[u8]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!("{file}.tmp"));
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, dir.join(file))
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// `<parent>/<name><suffix>` — a sibling path of `dir` (same parent).
fn sibling(dir: &Path, suffix: &str) -> PathBuf {
    let mut name = dir
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("ckpt"));
    name.push(suffix);
    dir.with_file_name(name)
}

/// The staging directory periodic checkpoints are written into before the
/// driver commits them: `<dir>.partial`, a sibling of the checkpoint dir
/// so the final rename swap stays on one filesystem. A `.partial` dir is
/// garbage by definition — only [`commit_staged`] turns one into a real
/// checkpoint, and it never contains a `train_state.json` until commit
/// time (so `load_*` on a torn dir fails loudly).
pub fn staging_dir(dir: &Path) -> PathBuf {
    sibling(dir, ".partial")
}

/// Delete any leftover staging (`<dir>.partial`) and swap-residue
/// (`<dir>.old`) directories — called before a run starts writing staged
/// state and before recovery re-shards the committed checkpoint.
pub fn discard_staging(dir: &Path) -> Result<()> {
    for leftover in [staging_dir(dir), sibling(dir, ".old")] {
        if leftover.exists() {
            std::fs::remove_dir_all(&leftover)
                .with_context(|| format!("clearing stale {}", leftover.display()))?;
        }
    }
    Ok(())
}

/// Commit the staged checkpoint: stamp `train_state.json` into the staging
/// dir (the validity marker every load path requires), then swap it into
/// place by rename — previous checkpoint to `<dir>.old`, staging to
/// `<dir>`, remove the old copy. A crash before the swap leaves the
/// previous checkpoint untouched; the one non-atomic window (between the
/// two renames) leaves a complete checkpoint under `<dir>.old` rather than
/// a torn one under `<dir>`.
pub fn commit_staged(dir: &Path, steps: usize, dp: usize, tp: usize) -> Result<()> {
    let staging = staging_dir(dir);
    if !staging.is_dir() {
        bail!("no staged checkpoint at {}", staging.display());
    }
    save_train_state(&staging, steps, dp, tp)?;
    let old = sibling(dir, ".old");
    if old.exists() {
        std::fs::remove_dir_all(&old)
            .with_context(|| format!("clearing stale {}", old.display()))?;
    }
    if dir.exists() {
        std::fs::rename(dir, &old)
            .with_context(|| format!("retiring previous checkpoint {}", dir.display()))?;
    }
    std::fs::rename(&staging, dir)
        .with_context(|| format!("committing staged checkpoint into {}", dir.display()))?;
    if old.exists() {
        std::fs::remove_dir_all(&old).ok(); // best-effort; .old is inert
    }
    Ok(())
}

/// Sorted UTF-8 file names in a checkpoint directory. Entries whose names
/// are not valid UTF-8 are **skipped** (with a note on stderr) rather than
/// panicked on: every file this module writes has an ASCII name, so a
/// non-UTF8 entry is by construction foreign garbage, not checkpoint
/// state. Before PR 8 the scan went through `into_string().unwrap()` and a
/// single such entry — a stray editor artifact, an rsync temp file — took
/// the whole process down.
pub fn dir_file_names(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    for e in
        std::fs::read_dir(dir).with_context(|| format!("scanning {}", dir.display()))?
    {
        let e = e.with_context(|| format!("scanning {}", dir.display()))?;
        match e.file_name().into_string() {
            Ok(name) => names.push(name),
            Err(os) => eprintln!(
                "checkpoint scan: skipping non-UTF8 entry {:?} in {}",
                os,
                dir.display()
            ),
        }
    }
    names.sort();
    Ok(names)
}

/// File name of one (stage, tp-rank)'s parameter checkpoint: tp = 1 keeps
/// the historic `stage<i>.bin` (drop-in for `artifacts/params/`); under
/// tensor parallelism every rank's expert-sharded vector is its own file.
pub fn stage_param_file(stage: usize, tp_rank: usize, tp: usize) -> String {
    if tp <= 1 {
        format!("stage{stage}.bin")
    } else {
        format!("stage{stage}.tp{tp_rank}of{tp}.bin")
    }
}

/// Write a parameter vector against an explicit layout (`<dir>/<file>`) —
/// the spec-generic core of [`save_stage`], used directly by the tp
/// trainer with each rank's [`crate::runtime::TpStageView`] layout.
pub fn save_params_with(
    dir: &Path,
    file: &str,
    specs: &[ParamSpec],
    params: &[Tensor],
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    if specs.len() != params.len() {
        bail!("{file}: {} tensors vs {} specs", params.len(), specs.len());
    }
    let mut bytes = Vec::with_capacity(specs.iter().map(|s| s.numel * 4).sum());
    for (t, spec) in params.iter().zip(specs) {
        if t.shape != spec.shape {
            bail!("checkpoint shape mismatch for {}", spec.name);
        }
        for v in t.as_f32()? {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    atomic_write(dir, file, &bytes).with_context(|| format!("writing checkpoint {file}"))?;
    Ok(())
}

/// Load a parameter vector by explicit layout — counterpart of
/// [`save_params_with`].
pub fn load_params_with(
    dir: &Path,
    file: &str,
    specs: &[ParamSpec],
    total_bytes: usize,
) -> Result<Vec<Tensor>> {
    let path = dir.join(file);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != total_bytes {
        bail!("{}: {} bytes, expected {}", path.display(), bytes.len(), total_bytes);
    }
    Ok(specs
        .iter()
        .map(|p| {
            let data: Vec<f32> = bytes[p.offset..p.offset + p.numel * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Tensor::f32(data, p.shape.clone())
        })
        .collect())
}

/// Write one stage's parameters as `<dir>/stage<i>.bin` (manifest layout).
pub fn save_stage(
    dir: &Path,
    stage: usize,
    manifest: &Manifest,
    params: &[Tensor],
) -> Result<()> {
    save_params_with(
        dir,
        &stage_param_file(stage, 0, 1),
        &manifest.stages[stage].params,
        params,
    )
    .with_context(|| format!("writing checkpoint stage {stage}"))
}

/// Load a stage's parameters from a checkpoint directory (manifest layout).
pub fn load_stage(dir: &Path, stage: usize, manifest: &Manifest) -> Result<Vec<Tensor>> {
    let sp = &manifest.stages[stage];
    load_params_with(dir, &stage_param_file(stage, 0, 1), &sp.params, sp.total_bytes)
}

/// File name of one (stage, dp-rank)'s optimizer shard: rank 0 keeps the
/// historic `stage<i>.opt.bin` (a dp = 1 checkpoint is byte-identical to a
/// pre-dp one), higher ranks write `stage<i>.rank<r>.opt.bin`. Public so
/// the trainer can pre-validate a resume directory on the driver before
/// any worker thread spawns.
pub fn optimizer_shard_file(stage: usize, rank: usize) -> String {
    if rank == 0 {
        format!("stage{stage}.opt.bin")
    } else {
        format!("stage{stage}.rank{rank}.opt.bin")
    }
}

/// [`optimizer_shard_file`] under tensor parallelism: each (stage,
/// tp-rank, dp-rank) owns its own moment-shard file; tp = 1 collapses to
/// the historic names so pre-tp checkpoints stay valid.
pub fn optimizer_shard_file_tp(
    stage: usize,
    tp_rank: usize,
    tp: usize,
    dp_rank: usize,
) -> String {
    if tp <= 1 {
        optimizer_shard_file(stage, dp_rank)
    } else {
        format!("stage{stage}.tp{tp_rank}of{tp}.rank{dp_rank}.opt.bin")
    }
}

/// Write one stage's sharded optimizer state as `<dir>/stage<i>.opt.bin`
/// (dp rank 0 / single replica — see [`save_optimizer_rank`]).
///
/// Layout (little-endian): `u64` chunk count, then per chunk `u64 step`,
/// `u64 lo`, `u64 hi` (the shard's flat element range) followed by
/// `hi − lo` f32 first moments and `hi − lo` f32 second moments. f32 bits
/// round-trip exactly, so a resumed step is bitwise-equal to an
/// uninterrupted one.
pub fn save_optimizer(dir: &Path, stage: usize, opts: &[ShardedAdam]) -> Result<()> {
    save_optimizer_rank(dir, stage, 0, opts)
}

/// [`save_optimizer`] for one data-parallel rank: at dp > 1 every replica
/// owns (and checkpoints) only its 1/dp moment shard per chunk, so a
/// checkpoint directory carries `dp` files per stage and resuming restores
/// each rank's shard to the replica that owns it — which is what keeps
/// resumption bitwise at dp > 1 (rust/tests/dp_equivalence.rs).
pub fn save_optimizer_rank(
    dir: &Path,
    stage: usize,
    rank: usize,
    opts: &[ShardedAdam],
) -> Result<()> {
    save_optimizer_file(dir, &optimizer_shard_file(stage, rank), opts)
}

/// [`save_optimizer_rank`] for one (tp-rank, dp-rank) — the tp trainer's
/// per-lane shard files (tp = 1 writes the historic names).
pub fn save_optimizer_tp(
    dir: &Path,
    stage: usize,
    tp_rank: usize,
    tp: usize,
    dp_rank: usize,
    opts: &[ShardedAdam],
) -> Result<()> {
    save_optimizer_file(dir, &optimizer_shard_file_tp(stage, tp_rank, tp, dp_rank), opts)
}

fn save_optimizer_file(dir: &Path, file: &str, opts: &[ShardedAdam]) -> Result<()> {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&(opts.len() as u64).to_le_bytes());
    for opt in opts {
        let (step, m, v) = opt.state();
        let owned = opt.owned();
        bytes.extend_from_slice(&step.to_le_bytes());
        bytes.extend_from_slice(&(owned.start as u64).to_le_bytes());
        bytes.extend_from_slice(&(owned.end as u64).to_le_bytes());
        for x in m {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        for x in v {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    atomic_write(dir, file, &bytes)
        .with_context(|| format!("writing optimizer state {file}"))?;
    Ok(())
}

/// Restore `<dir>/stage<i>.opt.bin` into freshly-constructed per-chunk
/// optimizers (dp rank 0 — see [`load_optimizer_rank`]). The shard layout
/// (chunk count and each chunk's owned flat range) must match — a
/// checkpoint from a different rank/group geometry fails loudly instead of
/// silently mis-assigning moments.
pub fn load_optimizer(dir: &Path, stage: usize, opts: &mut [ShardedAdam]) -> Result<()> {
    load_optimizer_rank(dir, stage, 0, opts)
}

/// [`load_optimizer`] for one data-parallel rank: reads
/// `stage<i>.rank<r>.opt.bin` (rank 0: the legacy `stage<i>.opt.bin`).
/// The per-chunk `lo..hi` check doubles as a dp-geometry check — a dp = 2
/// checkpoint loaded into a dp = 4 run owns different flat ranges and is
/// rejected before any moment is mis-assigned.
pub fn load_optimizer_rank(
    dir: &Path,
    stage: usize,
    rank: usize,
    opts: &mut [ShardedAdam],
) -> Result<()> {
    load_optimizer_file(dir, &optimizer_shard_file(stage, rank), opts)
}

/// [`load_optimizer_rank`] for one (tp-rank, dp-rank) lane shard.
pub fn load_optimizer_tp(
    dir: &Path,
    stage: usize,
    tp_rank: usize,
    tp: usize,
    dp_rank: usize,
    opts: &mut [ShardedAdam],
) -> Result<()> {
    load_optimizer_file(dir, &optimizer_shard_file_tp(stage, tp_rank, tp, dp_rank), opts)
}

fn take_u64(bytes: &[u8], cur: &mut usize) -> Result<u64> {
    if *cur + 8 > bytes.len() {
        bail!("truncated optimizer state at byte {cur}");
    }
    let v = u64::from_le_bytes(bytes[*cur..*cur + 8].try_into().unwrap());
    *cur += 8;
    Ok(v)
}

fn take_f32s(bytes: &[u8], cur: &mut usize, n: usize) -> Result<Vec<f32>> {
    if *cur + 4 * n > bytes.len() {
        bail!("truncated moment array at byte {cur}");
    }
    let out = bytes[*cur..*cur + 4 * n]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    *cur += 4 * n;
    Ok(out)
}

/// One chunk of an optimizer shard file, read raw (no target geometry):
/// `(step, lo, hi, m, v)`. Feeds [`reshard_optimizer`] and the torn-file
/// checks in [`validate_resume_dir`].
type RawOptChunk = (u64, usize, usize, Vec<f32>, Vec<f32>);

fn read_optimizer_raw(path: &Path) -> Result<Vec<RawOptChunk>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut cur = 0usize;
    let chunks = take_u64(&bytes, &mut cur)? as usize;
    let mut out = Vec::with_capacity(chunks);
    for _ in 0..chunks {
        let step = take_u64(&bytes, &mut cur)?;
        let lo = take_u64(&bytes, &mut cur)? as usize;
        let hi = take_u64(&bytes, &mut cur)? as usize;
        if hi < lo {
            bail!("{}: inverted shard range {lo}..{hi}", path.display());
        }
        let n = hi - lo;
        let m = take_f32s(&bytes, &mut cur, n)?;
        let v = take_f32s(&bytes, &mut cur, n)?;
        out.push((step, lo, hi, m, v));
    }
    if cur != bytes.len() {
        bail!("{}: {} trailing bytes", path.display(), bytes.len() - cur);
    }
    Ok(out)
}

/// Exact byte size of one rank's optimizer shard file over the given
/// per-chunk flat numels: the header `u64` plus, per chunk, 3 `u64`s and
/// the `2 · (hi − lo)` f32 moments of the [`segment`]`(rank, numel, dp)`
/// slice. The torn-file size check in [`validate_resume_dir`].
pub fn optimizer_file_bytes(chunk_numels: &[usize], rank: usize, dp: usize) -> usize {
    8 + chunk_numels
        .iter()
        .map(|&n| {
            let (lo, hi) = segment(rank, n, dp);
            24 + 8 * (hi - lo)
        })
        .sum::<usize>()
}

fn load_optimizer_file(dir: &Path, file: &str, opts: &mut [ShardedAdam]) -> Result<()> {
    let path = dir.join(file);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut cur = 0usize;
    let chunks = take_u64(&bytes, &mut cur)? as usize;
    if chunks != opts.len() {
        bail!(
            "{}: {} chunks in checkpoint vs {} optimizers",
            path.display(),
            chunks,
            opts.len()
        );
    }
    for opt in opts.iter_mut() {
        let step = take_u64(&bytes, &mut cur)?;
        let lo = take_u64(&bytes, &mut cur)? as usize;
        let hi = take_u64(&bytes, &mut cur)? as usize;
        if opt.owned() != (lo..hi) {
            bail!(
                "{}: checkpoint shard {lo}..{hi} vs optimizer shard {:?}",
                path.display(),
                opt.owned()
            );
        }
        let n = hi - lo;
        let m = take_f32s(&bytes, &mut cur, n)?;
        let v = take_f32s(&bytes, &mut cur, n)?;
        opt.restore_state(step, &m, &v)?;
    }
    if cur != bytes.len() {
        bail!("{}: {} trailing bytes", path.display(), bytes.len() - cur);
    }
    Ok(())
}

/// Record how many optimizer steps the checkpoint covers and the parallel
/// degrees it was taken at (`<dir>/train_state.json`) so a resumed run can
/// fast-forward the data stream to the exact position an uninterrupted run
/// would be at — and refuse to resume under a different dp or tp (the
/// optimizer shards, parameter sharding and per-replica data split all
/// depend on them).
pub fn save_train_state(dir: &Path, steps: usize, dp: usize, tp: usize) -> Result<()> {
    atomic_write(
        dir,
        "train_state.json",
        format!("{{\"steps\": {steps}, \"dp\": {dp}, \"tp\": {tp}}}\n").as_bytes(),
    )
    .context("writing train_state.json")?;
    Ok(())
}

/// `(steps, dp, tp)` recorded by [`save_train_state`]. Pre-dp checkpoints
/// (no `dp` key) load as dp = 1; pre-tp checkpoints as tp = 1.
pub fn load_train_state(dir: &Path) -> Result<(usize, usize, usize)> {
    let path = dir.join("train_state.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = crate::util::json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let steps = j
        .req("steps")?
        .as_usize()
        .context("train_state.json: steps")?;
    let opt = |k: &str| -> Result<usize> {
        match j.get(k) {
            Some(v) => v.as_usize().with_context(|| format!("train_state.json: {k}")),
            None => Ok(1),
        }
    };
    Ok((steps, opt("dp")?, opt("tp")?))
}

/// Per-chunk flat numels of one (stage, tp rank) view — the shard geometry
/// both the optimizer files and [`optimizer_file_bytes`] key off.
fn view_chunk_numels(view: &crate::runtime::TpStageView) -> Vec<usize> {
    (0..view.chunks.len())
        .map(|c| view.params[view.chunk_param_range(c)].iter().map(|p| p.numel).sum())
        .collect()
}

/// Full pre-spawn validation of a resume directory: the recorded (dp, tp)
/// must match the run's, every per-tp-rank parameter file must exist **at
/// its exact byte size**, and every (stage, tp rank, dp rank) optimizer
/// shard must exist at its exact byte size with every chunk's step counter
/// equal to the recorded step count. A torn or half-written directory
/// (something pre-atomic-commit crashes could produce, and foreign
/// checkpoints still can) fails here with the offending file named,
/// before any worker thread spawns. Returns the recorded step count.
pub fn validate_resume_dir(
    dir: &Path,
    manifest: &Manifest,
    dp: usize,
    tp: usize,
) -> Result<usize> {
    let (steps, ckpt_dp, ckpt_tp) =
        load_train_state(dir).context("resume checkpoint is missing train_state.json")?;
    if ckpt_dp != dp {
        bail!(
            "checkpoint was taken at dp={ckpt_dp}, cannot resume at dp={dp} \
             (optimizer shards and data split differ)"
        );
    }
    if ckpt_tp != tp {
        bail!(
            "checkpoint was taken at tp={ckpt_tp}, cannot resume at tp={tp} \
             (parameter and optimizer sharding differ)"
        );
    }
    for stage in 0..manifest.model.stages {
        for t in 0..tp {
            let view = manifest.stage_view(stage, t, tp)?;
            let bin = dir.join(stage_param_file(stage, t, tp));
            let meta = std::fs::metadata(&bin)
                .with_context(|| format!("resume checkpoint missing {}", bin.display()))?;
            if meta.len() as usize != view.total_bytes {
                bail!(
                    "{}: {} bytes, expected {} — torn or foreign checkpoint",
                    bin.display(),
                    meta.len(),
                    view.total_bytes
                );
            }
            let numels = view_chunk_numels(&view);
            for rank in 0..dp {
                let f = dir.join(optimizer_shard_file_tp(stage, t, tp, rank));
                let meta = std::fs::metadata(&f).with_context(|| {
                    format!(
                        "resume checkpoint missing {} (dp={dp} tp={tp} needs \
                         every lane's optimizer shard)",
                        f.display()
                    )
                })?;
                let want = optimizer_file_bytes(&numels, rank, dp);
                if meta.len() as usize != want {
                    bail!(
                        "{}: {} bytes, expected {} — torn or foreign checkpoint",
                        f.display(),
                        meta.len(),
                        want
                    );
                }
                for (c, (step, ..)) in read_optimizer_raw(&f)?.iter().enumerate() {
                    if *step as usize != steps {
                        bail!(
                            "{}: chunk {c} records optimizer step {step} but \
                             train_state.json says {steps} — torn checkpoint",
                            f.display()
                        );
                    }
                }
            }
        }
    }
    Ok(steps)
}

/// Re-partition a checkpoint's ZeRO-1 optimizer shards from `dp_old` to
/// `dp_new` ranks, in place. The full per-chunk moment state is
/// dp-invariant — rank r of n owns exactly the contiguous
/// [`segment`]`(r, numel, n)` slice — so resharding stitches the old
/// shards back together (verifying step agreement, contiguity, and the
/// segment contract as it goes) and re-slices along the new geometry.
/// Every f32 moves by `to_le_bytes`/`from_le_bytes`, so moments round-trip
/// bitwise: a run resumed from the resharded checkpoint at `dp_new` is
/// bit-identical to one launched at `dp_new` from the same full state
/// (rust/tests/elastic_equivalence.rs). Rewrites `train_state.json` with
/// the new dp and removes the excised ranks' stale shard files. This is
/// the elastic supervisor's rank-excision primitive
/// ([`super::train_supervised`]).
pub fn reshard_optimizer(
    dir: &Path,
    stages: usize,
    tp: usize,
    dp_old: usize,
    dp_new: usize,
) -> Result<()> {
    if dp_new == 0 {
        bail!("cannot reshard to dp=0");
    }
    if dp_old == dp_new {
        return Ok(());
    }
    let (steps, ckpt_dp, ckpt_tp) = load_train_state(dir)?;
    if ckpt_dp != dp_old {
        bail!(
            "{} records dp={ckpt_dp}, cannot reshard from dp_old={dp_old}",
            dir.display()
        );
    }
    if ckpt_tp != tp {
        bail!("{} records tp={ckpt_tp}, expected tp={tp}", dir.display());
    }
    for stage in 0..stages {
        for t in 0..tp {
            // 1. read every old rank's raw shard
            let shards: Vec<Vec<RawOptChunk>> = (0..dp_old)
                .map(|r| read_optimizer_raw(&dir.join(optimizer_shard_file_tp(stage, t, tp, r))))
                .collect::<Result<_>>()?;
            let nchunks = shards[0].len();
            if shards.iter().any(|s| s.len() != nchunks) {
                bail!("stage {stage} tp {t}: ranks disagree on chunk count");
            }
            // 2. stitch each chunk's full moment arrays back together,
            //    proving the shards really tile the flat range
            let mut full: Vec<(u64, Vec<f32>, Vec<f32>)> = Vec::with_capacity(nchunks);
            for c in 0..nchunks {
                let step = shards[0][c].0;
                let total = shards[dp_old - 1][c].2;
                let mut m = Vec::with_capacity(total);
                let mut v = Vec::with_capacity(total);
                let mut expect_lo = 0usize;
                for (r, shard) in shards.iter().enumerate() {
                    let (st, lo, hi, sm, sv) = &shard[c];
                    if *st != step {
                        bail!(
                            "stage {stage} tp {t} chunk {c}: rank {r} at \
                             optimizer step {st}, rank 0 at {step} — shards \
                             are from different checkpoints"
                        );
                    }
                    if *lo != expect_lo || (*lo, *hi) != segment(r, total, dp_old) {
                        bail!(
                            "stage {stage} tp {t} chunk {c}: rank {r} owns \
                             {lo}..{hi}, segment contract says {:?}",
                            segment(r, total, dp_old)
                        );
                    }
                    m.extend_from_slice(sm);
                    v.extend_from_slice(sv);
                    expect_lo = *hi;
                }
                full.push((step, m, v));
            }
            // 3. write the new geometry's shards (atomic, like any save)
            for r in 0..dp_new {
                let mut bytes = Vec::new();
                bytes.extend_from_slice(&(nchunks as u64).to_le_bytes());
                for (step, m, v) in &full {
                    let (lo, hi) = segment(r, m.len(), dp_new);
                    bytes.extend_from_slice(&step.to_le_bytes());
                    bytes.extend_from_slice(&(lo as u64).to_le_bytes());
                    bytes.extend_from_slice(&(hi as u64).to_le_bytes());
                    for x in &m[lo..hi] {
                        bytes.extend_from_slice(&x.to_le_bytes());
                    }
                    for x in &v[lo..hi] {
                        bytes.extend_from_slice(&x.to_le_bytes());
                    }
                }
                atomic_write(dir, &optimizer_shard_file_tp(stage, t, tp, r), &bytes)?;
            }
            // 4. the excised ranks' files are now stale — remove them so a
            //    later reshard (or validation) can't read a mixed geometry
            for r in dp_new..dp_old {
                std::fs::remove_file(dir.join(optimizer_shard_file_tp(stage, t, tp, r))).ok();
            }
        }
    }
    save_train_state(dir, steps, dp_new, tp)
}

/// Validation loss over `batches` held-out batches.
///
/// `checkpoint`: parameters to evaluate (None = the initial params shipped
/// with the artifacts). `structure_seed` must match the training corpus
/// (same language); `stream_seed` re-seeds the sampling so the batches are
/// held out.
pub fn evaluate(
    artifacts: &Path,
    checkpoint: Option<&Path>,
    batches: usize,
    structure_seed: u64,
    stream_seed: u64,
) -> Result<f32> {
    let mut rt = Runtime::open(artifacts)?;
    let m = rt.manifest.model.clone();
    let stages = m.stages;

    // tp-sharded checkpoints carry per-rank expert slices under segment-
    // ordered layouts (`stage<i>.tp<t>ofN.bin`) — the monolithic forward
    // chain below cannot consume them, so fail with the cause instead of
    // a bare "stage0.bin: No such file"
    if let Some(dir) = checkpoint {
        if let Ok((_, _, ckpt_tp)) = load_train_state(dir) {
            if ckpt_tp > 1 {
                bail!(
                    "checkpoint {} was taken at tp={ckpt_tp}: its parameters \
                     are expert-sharded per tensor rank and cannot feed the \
                     monolithic eval artifacts — evaluate a tp=1 run, or \
                     track the training loss (tp runs report it bitwise-\
                     equal to the tp reference)",
                    dir.display()
                );
            }
        }
    }

    let mut params = Vec::with_capacity(stages);
    for s in 0..stages {
        params.push(match checkpoint {
            Some(dir) => load_stage(dir, s, &rt.manifest)?,
            None => rt.load_stage_params(s)?,
        });
    }

    let v = m.virtual_stages;
    let mut corpus = Corpus::new(m.vocab, structure_seed);
    corpus.reseed_stream(stream_seed);
    let mut total = 0.0f32;
    for _ in 0..batches {
        let (tokens, targets) = corpus.batch(m.micro_batch, m.seq);
        let mut x = Tensor::i32(tokens, vec![m.micro_batch, m.seq]);
        let mut aux = 0.0f32;
        // chain the virtual stages in ring order: chunk c of stage p−1
        // wraps around into chunk c+1 of stage 0
        for vs in 0..stages * v - 1 {
            let (s, c) = (vs % stages, vs / stages);
            let name = rt.manifest.chunks[s][c]
                .fwd
                .clone()
                .context("non-loss chunk missing fwd artifact")?;
            let exe = rt.load(&name)?;
            let range = rt.manifest.chunk_param_range(s, c);
            let mut inputs = params[s][range].to_vec();
            inputs.push(x);
            let out = exe.run(&inputs)?;
            x = out[0].clone();
            aux += out[1].item()?;
        }
        let exe = rt.load("loss_eval")?;
        let range = rt.manifest.chunk_param_range(stages - 1, v - 1);
        let mut inputs = params[stages - 1][range].to_vec();
        inputs.push(x);
        inputs.push(Tensor::i32(targets, vec![m.micro_batch, m.seq]));
        inputs.push(Tensor::scalar_f32(aux));
        total += exe.run(&inputs)?[0].item()?;
    }
    Ok(total / batches as f32)
}

#[cfg(test)]
mod tests {
    // round-trip layout logic is covered here; PJRT-dependent paths are
    // exercised by rust/tests/trainer_and_tp.rs::checkpoint_eval_improves.
    use super::*;
    use crate::runtime::manifest::{Manifest, ParamSpec, StageParams};
    use crate::runtime::manifest::ModelInfo;
    use std::collections::BTreeMap;

    fn fake_manifest() -> Manifest {
        Manifest {
            model: ModelInfo {
                config_name: "t".into(), vocab: 4, hidden: 2, layers: 1,
                experts: 1, seq: 2, micro_batch: 1, stages: 1,
                virtual_stages: 1, aux_coef: 0.0, top_k: 1,
                capacity_factor: 2.0,
            },
            tp: 1,
            stages: vec![StageParams {
                bin: "params/stage0.bin".into(),
                total_bytes: 24,
                params: vec![
                    ParamSpec { name: "a".into(), shape: vec![2, 2], offset: 0, numel: 4 },
                    ParamSpec { name: "b".into(), shape: vec![2], offset: 16, numel: 2 },
                ],
            }],
            chunks: vec![vec![crate::runtime::manifest::ChunkSpec {
                fwd: None,
                bwd: "lossgrad".into(),
                params: 2,
            }]],
            tp_exec: None,
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ppmoe_ckpt_{}", std::process::id()));
        let m = fake_manifest();
        let params = vec![
            Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]),
            Tensor::f32(vec![5.0, 6.0], vec![2]),
        ];
        save_stage(&dir, 0, &m, &params).unwrap();
        let loaded = load_stage(&dir, 0, &m).unwrap();
        assert_eq!(loaded, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn optimizer_state_roundtrip_resumes_bitwise() {
        // The satellite contract, host-side: params + per-chunk sharded
        // Adam moments round-trip through save/load, and one step taken
        // after the round-trip is BITWISE equal to one taken without it.
        let dir = std::env::temp_dir().join(format!("ppmoe_opt_{}", std::process::id()));
        let m = fake_manifest(); // 2 tensors, treated as 2 chunks below
        let mut params = vec![
            Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]),
            Tensor::f32(vec![5.0, 6.0], vec![2]),
        ];
        let grads = vec![
            Tensor::f32(vec![0.5, -0.25, 0.125, 1.0], vec![2, 2]),
            Tensor::f32(vec![-0.75, 0.375], vec![2]),
        ];
        // chunk 0 owns tensor 0, chunk 1 owns tensor 1 (single-rank shards)
        let mut opts = vec![
            ShardedAdam::new(0.05, &params[..1], 0, 1),
            ShardedAdam::new(0.05, &params[1..], 0, 1),
        ];
        for _ in 0..3 {
            opts[0].update_shard(&mut params[..1], &grads[..1], 0.5).unwrap();
            opts[1].update_shard(&mut params[1..], &grads[1..], 0.5).unwrap();
        }
        save_stage(&dir, 0, &m, &params).unwrap();
        save_optimizer(&dir, 0, &opts).unwrap();
        save_train_state(&dir, 3, 1, 1).unwrap();

        // uninterrupted continuation
        let mut p_cont = params.clone();
        opts[0].update_shard(&mut p_cont[..1], &grads[..1], 0.5).unwrap();
        opts[1].update_shard(&mut p_cont[1..], &grads[1..], 0.5).unwrap();

        // resumed continuation from disk
        let mut p_res = load_stage(&dir, 0, &m).unwrap();
        let mut opts_res = vec![
            ShardedAdam::new(0.05, &p_res[..1], 0, 1),
            ShardedAdam::new(0.05, &p_res[1..], 0, 1),
        ];
        load_optimizer(&dir, 0, &mut opts_res).unwrap();
        assert_eq!(load_train_state(&dir).unwrap(), (3, 1, 1));
        opts_res[0].update_shard(&mut p_res[..1], &grads[..1], 0.5).unwrap();
        opts_res[1].update_shard(&mut p_res[1..], &grads[1..], 0.5).unwrap();

        assert_eq!(p_cont, p_res, "resumed step must be bitwise-identical");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn optimizer_load_rejects_mismatched_shards() {
        let dir = std::env::temp_dir().join(format!("ppmoe_opt2_{}", std::process::id()));
        let params = vec![Tensor::f32(vec![0.0; 10], vec![10])];
        let opts = vec![ShardedAdam::new(0.01, &params, 0, 1)];
        save_optimizer(&dir, 0, &opts).unwrap();
        // wrong chunk count
        let mut two = vec![
            ShardedAdam::new(0.01, &params, 0, 1),
            ShardedAdam::new(0.01, &params, 0, 1),
        ];
        assert!(load_optimizer(&dir, 0, &mut two).is_err());
        // wrong shard geometry (rank 1 of 2 owns a different flat range)
        let mut wrong = vec![ShardedAdam::new(0.01, &params, 1, 2)];
        assert!(load_optimizer(&dir, 0, &mut wrong).is_err());
        // missing stage file
        let mut ok = vec![ShardedAdam::new(0.01, &params, 0, 1)];
        assert!(load_optimizer(&dir, 7, &mut ok).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_state_roundtrip_and_missing() {
        let dir = std::env::temp_dir().join(format!("ppmoe_ts_{}", std::process::id()));
        save_train_state(&dir, 42, 2, 2).unwrap();
        assert_eq!(load_train_state(&dir).unwrap(), (42, 2, 2));
        // a pre-dp/pre-tp checkpoint (no keys) loads as dp = tp = 1
        std::fs::write(dir.join("train_state.json"), "{\"steps\": 7}\n").unwrap();
        assert_eq!(load_train_state(&dir).unwrap(), (7, 1, 1));
        std::fs::remove_dir_all(&dir).ok();
        assert!(load_train_state(&dir).is_err());
    }

    #[test]
    fn tp_shard_file_names_collapse_at_tp1() {
        // tp = 1 keeps every historic name (old checkpoints stay valid)
        assert_eq!(stage_param_file(3, 0, 1), "stage3.bin");
        assert_eq!(optimizer_shard_file_tp(3, 0, 1, 0), "stage3.opt.bin");
        assert_eq!(optimizer_shard_file_tp(3, 0, 1, 2), "stage3.rank2.opt.bin");
        // tp > 1: every (tp, dp) lane owns its own files
        assert_eq!(stage_param_file(3, 1, 2), "stage3.tp1of2.bin");
        assert_eq!(optimizer_shard_file_tp(3, 1, 2, 0), "stage3.tp1of2.rank0.opt.bin");
        assert_eq!(optimizer_shard_file_tp(0, 0, 4, 3), "stage0.tp0of4.rank3.opt.bin");
    }

    #[test]
    fn tp_lane_checkpoints_roundtrip() {
        // per-(tp, dp) optimizer shards + spec-layout param files
        let dir =
            std::env::temp_dir().join(format!("ppmoe_tpck_{}", std::process::id()));
        let params = vec![Tensor::f32(vec![1.0, 2.0, 3.0], vec![3])];
        let specs = vec![ParamSpec {
            name: "w".into(),
            shape: vec![3],
            offset: 0,
            numel: 3,
        }];
        save_params_with(&dir, &stage_param_file(0, 1, 2), &specs, &params).unwrap();
        let loaded =
            load_params_with(&dir, &stage_param_file(0, 1, 2), &specs, 12).unwrap();
        assert_eq!(loaded, params);

        let grads = vec![Tensor::f32(vec![0.5, -0.5, 0.25], vec![3])];
        let mut opts = vec![ShardedAdam::new(0.05, &params, 0, 1)];
        let mut p = params.clone();
        opts[0].update_shard(&mut p, &grads, 1.0).unwrap();
        save_optimizer_tp(&dir, 0, 1, 2, 0, &opts).unwrap();
        assert!(dir.join("stage0.tp1of2.rank0.opt.bin").exists());
        let mut fresh = vec![ShardedAdam::new(0.05, &params, 0, 1)];
        load_optimizer_tp(&dir, 0, 1, 2, 0, &mut fresh).unwrap();
        assert_eq!(fresh[0].state(), opts[0].state());
        // wrong lane file is absent
        let mut other = vec![ShardedAdam::new(0.05, &params, 0, 1)];
        assert!(load_optimizer_tp(&dir, 0, 0, 2, 0, &mut other).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn per_rank_optimizer_shards_roundtrip_and_reject_geometry() {
        // dp = 2: each rank checkpoints its own half-moments; loading
        // restores exactly the owning rank's shard and refuses a shard
        // from a different dp geometry.
        let dir = std::env::temp_dir().join(format!("ppmoe_optdp_{}", std::process::id()));
        let params = vec![Tensor::f32((0..10).map(|i| i as f32).collect(), vec![10])];
        let grads = vec![Tensor::f32(vec![0.25; 10], vec![10])];
        let dp = 2;
        let mut rank_opts: Vec<Vec<ShardedAdam>> = (0..dp)
            .map(|r| vec![ShardedAdam::new(0.05, &params, r, dp)])
            .collect();
        for (r, opts) in rank_opts.iter_mut().enumerate() {
            let mut p = params.clone();
            opts[0].update_shard(&mut p, &grads, 1.0).unwrap();
            save_optimizer_rank(&dir, 0, r, opts).unwrap();
        }
        // rank 0's file is the legacy name; rank 1's is rank-suffixed
        assert!(dir.join("stage0.opt.bin").exists());
        assert!(dir.join("stage0.rank1.opt.bin").exists());
        for r in 0..dp {
            let mut fresh = vec![ShardedAdam::new(0.05, &params, r, dp)];
            load_optimizer_rank(&dir, 0, r, &mut fresh).unwrap();
            let (step, m, v) = fresh[0].state();
            let (step0, m0, v0) = rank_opts[r][0].state();
            assert_eq!((step, m, v), (step0, m0, v0), "rank {r} shard diverged");
        }
        // wrong geometry: a dp = 4 shard owns a different flat range
        let mut wrong = vec![ShardedAdam::new(0.05, &params, 1, 4)];
        assert!(load_optimizer_rank(&dir, 0, 1, &mut wrong).is_err());
        // missing rank file
        let mut r2 = vec![ShardedAdam::new(0.05, &params, 1, 2)];
        assert!(load_optimizer_rank(&dir, 1, 1, &mut r2).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_writes_leave_no_tmp_files() {
        let dir = std::env::temp_dir().join(format!("ppmoe_atomic_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let m = fake_manifest();
        let params = vec![
            Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]),
            Tensor::f32(vec![5.0, 6.0], vec![2]),
        ];
        save_stage(&dir, 0, &m, &params).unwrap();
        save_optimizer(&dir, 0, &[ShardedAdam::new(0.05, &params, 0, 1)]).unwrap();
        save_train_state(&dir, 1, 1, 1).unwrap();
        for name in dir_file_names(&dir).unwrap() {
            assert!(!name.ends_with(".tmp"), "leftover temp file {name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Regression (PR 8): a non-UTF8 filename in a checkpoint directory
    /// must not panic the scan — and must not break loading the real
    /// checkpoint files next to it.
    #[test]
    #[cfg(unix)]
    fn non_utf8_entries_are_skipped_not_fatal() {
        use std::os::unix::ffi::OsStrExt;
        let dir = std::env::temp_dir().join(format!("ppmoe_nonutf8_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let m = fake_manifest();
        let params = vec![
            Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]),
            Tensor::f32(vec![5.0, 6.0], vec![2]),
        ];
        save_stage(&dir, 0, &m, &params).unwrap();
        save_train_state(&dir, 1, 1, 1).unwrap();
        // 0x80 0xFF is not valid UTF-8 in any position
        let evil = std::ffi::OsStr::from_bytes(&[b'g', b'a', b'r', 0x80, 0xFF]);
        std::fs::write(dir.join(evil), b"junk").unwrap();
        let names = dir_file_names(&dir).unwrap();
        assert!(
            names.contains(&"stage0.bin".to_string())
                && names.contains(&"train_state.json".to_string()),
            "real checkpoint files must survive the scan: {names:?}"
        );
        assert_eq!(names.len(), 2, "the non-UTF8 entry is skipped: {names:?}");
        // and the load path next to the junk entry still works
        assert_eq!(load_stage(&dir, 0, &m).unwrap(), params);
        assert_eq!(load_train_state(&dir).unwrap(), (1, 1, 1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn staged_commit_swaps_atomically() {
        let base = std::env::temp_dir().join(format!("ppmoe_stage_{}", std::process::id()));
        std::fs::remove_dir_all(&base).ok();
        let dir = base.join("ckpt");
        let m = fake_manifest();
        let p1 = vec![
            Tensor::f32(vec![1.0; 4], vec![2, 2]),
            Tensor::f32(vec![1.0; 2], vec![2]),
        ];
        let p2 = vec![
            Tensor::f32(vec![2.0; 4], vec![2, 2]),
            Tensor::f32(vec![2.0; 2], vec![2]),
        ];
        // committing with nothing staged is an error
        assert!(commit_staged(&dir, 1, 1, 1).is_err());
        // stage + commit v1, then v2 over it
        save_stage(&staging_dir(&dir), 0, &m, &p1).unwrap();
        commit_staged(&dir, 1, 1, 1).unwrap();
        assert_eq!(load_train_state(&dir).unwrap(), (1, 1, 1));
        assert_eq!(load_stage(&dir, 0, &m).unwrap(), p1);
        assert!(!staging_dir(&dir).exists(), "staging dir must be consumed");
        save_stage(&staging_dir(&dir), 0, &m, &p2).unwrap();
        commit_staged(&dir, 2, 1, 1).unwrap();
        assert_eq!(load_train_state(&dir).unwrap(), (2, 1, 1));
        assert_eq!(load_stage(&dir, 0, &m).unwrap(), p2);
        assert!(!sibling(&dir, ".old").exists(), "swap residue must be cleaned");
        // a torn staging dir has no train_state.json (only commit writes
        // it), so load paths reject it; discard leaves the committed
        // checkpoint untouched
        save_stage(&staging_dir(&dir), 0, &m, &p1).unwrap();
        assert!(load_train_state(&staging_dir(&dir)).is_err());
        discard_staging(&dir).unwrap();
        assert!(!staging_dir(&dir).exists());
        assert_eq!(load_stage(&dir, 0, &m).unwrap(), p2);
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn reshard_2_to_1_is_bitwise() {
        // the elastic contract, host-side: the full moment state is
        // dp-invariant, so stitching dp = 2 shards and re-slicing to
        // dp = 1 reproduces a native dp = 1 optimizer bit for bit
        let dir = std::env::temp_dir().join(format!("ppmoe_reshard_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let params = vec![Tensor::f32((0..11).map(|i| 0.1 * i as f32).collect(), vec![11])];
        let grads =
            vec![Tensor::f32((0..11).map(|i| 0.01 * (i as f32 - 5.0)).collect(), vec![11])];
        let mut rank_opts: Vec<Vec<ShardedAdam>> =
            (0..2).map(|r| vec![ShardedAdam::new(0.05, &params, r, 2)]).collect();
        let mut reference = vec![ShardedAdam::new(0.05, &params, 0, 1)];
        for _ in 0..3 {
            for opts in rank_opts.iter_mut() {
                let mut p = params.clone();
                opts[0].update_shard(&mut p, &grads, 0.5).unwrap();
            }
            let mut p = params.clone();
            reference[0].update_shard(&mut p, &grads, 0.5).unwrap();
        }
        for (r, opts) in rank_opts.iter().enumerate() {
            save_optimizer_rank(&dir, 0, r, opts).unwrap();
        }
        save_train_state(&dir, 3, 2, 1).unwrap();

        reshard_optimizer(&dir, 1, 1, 2, 1).unwrap();
        assert_eq!(load_train_state(&dir).unwrap(), (3, 1, 1));
        assert!(
            !dir.join("stage0.rank1.opt.bin").exists(),
            "excised rank's shard must be removed"
        );
        let mut restored = vec![ShardedAdam::new(0.05, &params, 0, 1)];
        load_optimizer(&dir, 0, &mut restored).unwrap();
        let (step, m, v) = restored[0].state();
        let (step_ref, m_ref, v_ref) = reference[0].state();
        assert_eq!(step, step_ref);
        assert_eq!(m, m_ref, "first moments must reshard bitwise");
        assert_eq!(v, v_ref, "second moments must reshard bitwise");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reshard_rejects_mixed_step_shards() {
        let dir = std::env::temp_dir().join(format!("ppmoe_reshard2_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let params = vec![Tensor::f32(vec![0.0; 8], vec![8])];
        let grads = vec![Tensor::f32(vec![0.5; 8], vec![8])];
        let mut r0 = vec![ShardedAdam::new(0.05, &params, 0, 2)];
        let mut r1 = vec![ShardedAdam::new(0.05, &params, 1, 2)];
        let mut p = params.clone();
        r0[0].update_shard(&mut p, &grads, 1.0).unwrap();
        r0[0].update_shard(&mut p, &grads, 1.0).unwrap();
        r1[0].update_shard(&mut p, &grads, 1.0).unwrap(); // one step behind
        save_optimizer_rank(&dir, 0, 0, &r0).unwrap();
        save_optimizer_rank(&dir, 0, 1, &r1).unwrap();
        save_train_state(&dir, 2, 2, 1).unwrap();
        let err = reshard_optimizer(&dir, 1, 1, 2, 1).unwrap_err().to_string();
        assert!(err.contains("different checkpoints"), "got: {err}");
        // a missing rank file is an error, not a silent partial reshard
        std::fs::remove_file(dir.join("stage0.rank1.opt.bin")).unwrap();
        assert!(reshard_optimizer(&dir, 1, 1, 2, 1).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_resume_dir_flags_torn_dirs() {
        let dir = std::env::temp_dir().join(format!("ppmoe_val_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let m = fake_manifest();
        let params = vec![
            Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]),
            Tensor::f32(vec![5.0, 6.0], vec![2]),
        ];
        let grads = vec![
            Tensor::f32(vec![0.5; 4], vec![2, 2]),
            Tensor::f32(vec![0.25; 2], vec![2]),
        ];
        let mut opts = vec![ShardedAdam::new(0.05, &params, 0, 1)];
        let mut p = params.clone();
        for _ in 0..3 {
            opts[0].update_shard(&mut p, &grads, 1.0).unwrap();
        }
        save_stage(&dir, 0, &m, &p).unwrap();
        save_optimizer(&dir, 0, &opts).unwrap();
        save_train_state(&dir, 3, 1, 1).unwrap();
        assert_eq!(validate_resume_dir(&dir, &m, 1, 1).unwrap(), 3);
        // recorded-geometry mismatches
        assert!(validate_resume_dir(&dir, &m, 2, 1).is_err());
        assert!(validate_resume_dir(&dir, &m, 1, 2).is_err());
        // torn parameter file (truncated mid-write)
        let bin = dir.join("stage0.bin");
        let full = std::fs::read(&bin).unwrap();
        std::fs::write(&bin, &full[..10]).unwrap();
        assert!(validate_resume_dir(&dir, &m, 1, 1).is_err());
        std::fs::write(&bin, &full).unwrap();
        assert_eq!(validate_resume_dir(&dir, &m, 1, 1).unwrap(), 3);
        // torn optimizer shard
        let opt_file = dir.join("stage0.opt.bin");
        let obytes = std::fs::read(&opt_file).unwrap();
        std::fs::write(&opt_file, &obytes[..obytes.len() - 4]).unwrap();
        assert!(validate_resume_dir(&dir, &m, 1, 1).is_err());
        std::fs::write(&opt_file, &obytes).unwrap();
        // optimizer step counters out of sync with train_state.json
        save_train_state(&dir, 4, 1, 1).unwrap();
        assert!(validate_resume_dir(&dir, &m, 1, 1).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_rejects_wrong_shapes() {
        let dir = std::env::temp_dir().join(format!("ppmoe_ckpt2_{}", std::process::id()));
        let m = fake_manifest();
        let bad = vec![
            Tensor::f32(vec![1.0; 2], vec![2]), // wrong shape for "a"
            Tensor::f32(vec![5.0, 6.0], vec![2]),
        ];
        assert!(save_stage(&dir, 0, &m, &bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
