//! Slab pools: activation/gradient/output payloads are recycled instead of
//! being freshly allocated for every send.
//!
//! Two variants share the same counter semantics:
//!
//! - [`SlabPool`]/[`SlabReturn`] — the per-edge mpsc pair used by the
//!   trainer. Each pipeline edge (the p2p link of §3.1.3) gets a
//!   back-channel carrying spent `Vec<f32>` storage from the consumer back
//!   to the producer. The producer reads the next payload *into* a
//!   reclaimed slab ([`SlabPool::take`]), the consumer uploads it to its
//!   device and returns the storage ([`SlabReturn::put`]).
//! - [`LocalSlabPool`] — a same-thread free-list with identical accounting,
//!   used by the forward-only serving engine (`serve/`) for request
//!   activation and output payloads, where producer and consumer are the
//!   same thread and a channel would be overhead.
//!
//! After warmup the steady state hands out zero fresh allocations; the
//! counters exist to *certify* that. The invariant they certify is
//!
//! ```text
//! total allocations == misses + prefilled
//! ```
//!
//! `hits` counts only genuinely recycled storage. Pre-seeded slabs
//! ([`SlabPool::prefill`]) are fresh allocations made up-front — they are
//! tracked in the separate `prefilled` counter, not as hits (which would
//! hide the allocation) nor as take-time misses (the allocation does not
//! happen on the hot path). A steady state is zero-alloc iff `misses` stops
//! growing and `prefilled` equals the fixed seed count.
//!
//! The channel pair is deliberately asymmetric: the pool (producer side)
//! never blocks — if the consumer hasn't returned a slab yet (warmup, or a
//! deep 1F1B in-flight window), `take` just allocates. Capacity converges
//! on the schedule's peak in-flight count.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

/// Producer side: hands out payload buffers, preferring recycled storage.
pub struct SlabPool {
    reclaim: Receiver<Vec<f32>>,
    /// Producer-local pre-seeded slabs ([`SlabPool::prefill`]), consumed
    /// before the reclaim channel is consulted.
    seeded: Vec<Vec<f32>>,
    /// Fresh allocations handed out at take time (steady state: stops
    /// growing).
    pub misses: u64,
    /// Recycled slabs handed out (returned by the consumer and reused).
    pub hits: u64,
    /// Fresh slabs allocated up-front by [`SlabPool::prefill`]. Counted
    /// here — not as hits or misses — so `misses + prefilled` is the true
    /// allocation count.
    pub prefilled: u64,
}

/// Consumer side: returns spent payload storage to the producer.
#[derive(Clone)]
pub struct SlabReturn {
    tx: Sender<Vec<f32>>,
}

/// One edge's recycling pair.
pub fn slab_pair() -> (SlabPool, SlabReturn) {
    let (tx, rx) = channel();
    (
        SlabPool { reclaim: rx, seeded: Vec::new(), misses: 0, hits: 0, prefilled: 0 },
        SlabReturn { tx },
    )
}

impl SlabPool {
    /// Pre-seed the pool with `count` producer-local slabs of `len`
    /// capacity, served before the reclaim channel. Wrap-around edges use
    /// `prefill(2, ..)` for **double buffering**: one slab can sit staged
    /// on the producer (d2h issued, send deferred) while the previous one
    /// drains through the channel — with zero warmup misses. The `count`
    /// fresh allocations are recorded in [`SlabPool::prefilled`].
    pub fn prefill(&mut self, count: usize, len: usize) {
        for _ in 0..count {
            self.seeded.push(Vec::with_capacity(len));
        }
        self.prefilled += count as u64;
    }

    /// A cleared buffer with capacity for `len` elements — pre-seeded or
    /// recycled if available, freshly allocated (a miss) otherwise.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        if let Some(mut v) = self.seeded.pop() {
            // Neither hit nor miss: the allocation was already counted in
            // `prefilled` when the slab was seeded.
            v.clear();
            v.reserve(len);
            return v;
        }
        match self.reclaim.try_recv() {
            Ok(mut v) => {
                self.hits += 1;
                v.clear();
                v.reserve(len);
                v
            }
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                self.misses += 1;
                Vec::with_capacity(len)
            }
        }
    }
}

impl SlabReturn {
    /// Give storage back to the producer. A disconnected producer (shutdown
    /// order) is fine — the storage is simply dropped.
    pub fn put(&self, v: Vec<f32>) {
        self.tx.send(v).ok();
    }
}

/// Same-thread slab pool: identical accounting to [`SlabPool`], but
/// producer and consumer are one thread so recycling is a plain free-list
/// push instead of an mpsc round-trip. The serving engine uses one of these
/// for request activation/output payloads.
#[derive(Default)]
pub struct LocalSlabPool {
    free: Vec<Vec<f32>>,
    seeded: Vec<Vec<f32>>,
    /// Fresh allocations handed out at take time.
    pub misses: u64,
    /// Recycled slabs handed out.
    pub hits: u64,
    /// Fresh slabs allocated up-front by [`LocalSlabPool::prefill`].
    pub prefilled: u64,
}

impl LocalSlabPool {
    /// An empty pool: every early `take` is a miss until slabs come back.
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-seed `count` slabs of `len` capacity (counted in `prefilled`).
    pub fn prefill(&mut self, count: usize, len: usize) {
        for _ in 0..count {
            self.seeded.push(Vec::with_capacity(len));
        }
        self.prefilled += count as u64;
    }

    /// A cleared buffer with capacity for `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        if let Some(mut v) = self.seeded.pop() {
            v.clear();
            v.reserve(len);
            return v;
        }
        if let Some(mut v) = self.free.pop() {
            self.hits += 1;
            v.clear();
            v.reserve(len);
            return v;
        }
        self.misses += 1;
        Vec::with_capacity(len)
    }

    /// Return spent storage for reuse.
    pub fn put(&mut self, v: Vec<f32>) {
        self.free.push(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_returned_storage() {
        let (mut pool, ret) = slab_pair();
        let a = pool.take(16);
        assert_eq!(pool.misses, 1);
        let ptr = a.as_ptr();
        ret.put(a);
        let b = pool.take(8);
        assert_eq!(pool.hits, 1);
        assert_eq!(b.as_ptr(), ptr, "storage must be reused");
        assert!(b.is_empty() && b.capacity() >= 8);
    }

    #[test]
    fn empty_pool_allocates() {
        let (mut pool, _ret) = slab_pair();
        let v = pool.take(4);
        assert!(v.capacity() >= 4);
        assert_eq!((pool.hits, pool.misses), (0, 1));
    }

    #[test]
    fn survives_disconnected_ends() {
        let (mut pool, ret) = slab_pair();
        drop(ret);
        assert!(pool.take(4).capacity() >= 4); // no panic on disconnect
        let (pool2, ret2) = slab_pair();
        drop(pool2);
        ret2.put(vec![1.0]); // no panic either
    }

    /// Regression (PR 8): prefilled slabs are *fresh allocations*, not
    /// hits. Counting them as hits hid real allocations from the
    /// zero-alloc certificate — `prefill(2, ..)` + two takes used to
    /// report (hits, misses) = (2, 0) as if storage had been recycled.
    #[test]
    fn prefill_serves_before_allocating() {
        let (mut pool, ret) = slab_pair();
        pool.prefill(2, 16);
        assert_eq!(pool.prefilled, 2, "prefill allocations counted up-front");
        let a = pool.take(8);
        let b = pool.take(8);
        assert_eq!(
            (pool.hits, pool.misses, pool.prefilled),
            (0, 0, 2),
            "pre-seeded takes are neither hits nor misses"
        );
        assert!(a.capacity() >= 16 && b.capacity() >= 16);
        // once drained, the pool falls back to reclaim-or-allocate
        ret.put(a);
        let c = pool.take(8);
        assert_eq!(
            (pool.hits, pool.misses, pool.prefilled),
            (1, 0, 2),
            "a recycled slab is the only kind of hit"
        );
        drop(c);
        let _d = pool.take(8);
        assert_eq!((pool.hits, pool.misses, pool.prefilled), (1, 1, 2));
    }

    /// The certified invariant: every slab ever handed out is accounted as
    /// exactly one of {hit, miss, prefilled-seed}.
    #[test]
    fn allocation_accounting_is_total() {
        let (mut pool, ret) = slab_pair();
        pool.prefill(1, 8);
        let mut takes = 0u64;
        let mut held = Vec::new();
        for i in 0..10 {
            held.push(pool.take(8));
            takes += 1;
            if i % 2 == 1 {
                ret.put(held.remove(0));
            }
        }
        // prefilled counts seeds (1), not takes served from seeds; the
        // seed-served take is the gap between takes and hits+misses.
        assert_eq!(pool.hits + pool.misses + pool.prefilled, takes);
        assert_eq!(pool.prefilled, 1);
    }

    #[test]
    fn grows_capacity_on_demand() {
        let (mut pool, ret) = slab_pair();
        ret.put(Vec::with_capacity(2));
        let v = pool.take(64);
        assert!(v.capacity() >= 64, "reserve must honor the larger request");
        assert_eq!(pool.hits, 1);
    }

    #[test]
    fn local_pool_matches_channel_pool_accounting() {
        let mut pool = LocalSlabPool::new();
        pool.prefill(1, 16);
        let a = pool.take(8);
        assert_eq!((pool.hits, pool.misses, pool.prefilled), (0, 0, 1));
        let b = pool.take(8);
        assert_eq!((pool.hits, pool.misses, pool.prefilled), (0, 1, 1));
        let ptr = a.as_ptr();
        pool.put(a);
        let c = pool.take(4);
        assert_eq!((pool.hits, pool.misses, pool.prefilled), (1, 1, 1));
        assert_eq!(c.as_ptr(), ptr, "free-list storage must be reused");
        drop(b);
        drop(c);
    }
}
