//! Per-edge slab pools: activation/gradient payloads are recycled across
//! microbatches instead of being freshly allocated for every mpsc send.
//!
//! Each pipeline edge (the p2p link of §3.1.3) gets a back-channel
//! carrying spent `Vec<f32>` storage from the consumer back to the
//! producer. The producer reads the next payload *into* a reclaimed slab
//! (`SlabPool::take`), the consumer uploads it to its device and returns
//! the storage (`SlabReturn::put`). After the pipeline's warmup rounds the
//! steady state sends zero fresh allocations over any edge.
//!
//! The channel pair is deliberately asymmetric: the pool (producer side)
//! never blocks — if the consumer hasn't returned a slab yet (warmup, or a
//! deep 1F1B in-flight window), `take` just allocates. Capacity converges
//! on the schedule's peak in-flight count.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

/// Producer side: hands out payload buffers, preferring recycled storage.
pub struct SlabPool {
    reclaim: Receiver<Vec<f32>>,
    /// Producer-local pre-seeded slabs ([`SlabPool::prefill`]), consumed
    /// before the reclaim channel is consulted.
    prefilled: Vec<Vec<f32>>,
    /// Fresh allocations handed out (steady state: stops growing).
    pub misses: u64,
    /// Recycled slabs handed out.
    pub hits: u64,
}

/// Consumer side: returns spent payload storage to the producer.
#[derive(Clone)]
pub struct SlabReturn {
    tx: Sender<Vec<f32>>,
}

/// One edge's recycling pair.
pub fn slab_pair() -> (SlabPool, SlabReturn) {
    let (tx, rx) = channel();
    (
        SlabPool { reclaim: rx, prefilled: Vec::new(), misses: 0, hits: 0 },
        SlabReturn { tx },
    )
}

impl SlabPool {
    /// Pre-seed the pool with `count` producer-local slabs of `len`
    /// capacity, served before the reclaim channel. Wrap-around edges use
    /// `prefill(2, ..)` for **double buffering**: one slab can sit staged
    /// on the producer (d2h issued, send deferred) while the previous one
    /// drains through the channel — with zero warmup misses.
    pub fn prefill(&mut self, count: usize, len: usize) {
        for _ in 0..count {
            self.prefilled.push(Vec::with_capacity(len));
        }
    }

    /// A cleared buffer with capacity for `len` elements — recycled if the
    /// consumer has returned one, freshly allocated otherwise.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        if let Some(mut v) = self.prefilled.pop() {
            self.hits += 1;
            v.clear();
            v.reserve(len);
            return v;
        }
        match self.reclaim.try_recv() {
            Ok(mut v) => {
                self.hits += 1;
                v.clear();
                v.reserve(len);
                v
            }
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                self.misses += 1;
                Vec::with_capacity(len)
            }
        }
    }
}

impl SlabReturn {
    /// Give storage back to the producer. A disconnected producer (shutdown
    /// order) is fine — the storage is simply dropped.
    pub fn put(&self, v: Vec<f32>) {
        self.tx.send(v).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_returned_storage() {
        let (mut pool, ret) = slab_pair();
        let a = pool.take(16);
        assert_eq!(pool.misses, 1);
        let ptr = a.as_ptr();
        ret.put(a);
        let b = pool.take(8);
        assert_eq!(pool.hits, 1);
        assert_eq!(b.as_ptr(), ptr, "storage must be reused");
        assert!(b.is_empty() && b.capacity() >= 8);
    }

    #[test]
    fn empty_pool_allocates() {
        let (mut pool, _ret) = slab_pair();
        let v = pool.take(4);
        assert!(v.capacity() >= 4);
        assert_eq!((pool.hits, pool.misses), (0, 1));
    }

    #[test]
    fn survives_disconnected_ends() {
        let (mut pool, ret) = slab_pair();
        drop(ret);
        assert!(pool.take(4).capacity() >= 4); // no panic on disconnect
        let (pool2, ret2) = slab_pair();
        drop(pool2);
        ret2.put(vec![1.0]); // no panic either
    }

    #[test]
    fn prefill_serves_before_allocating() {
        let (mut pool, ret) = slab_pair();
        pool.prefill(2, 16);
        let a = pool.take(8);
        let b = pool.take(8);
        assert_eq!((pool.hits, pool.misses), (2, 0), "prefilled slabs are hits");
        assert!(a.capacity() >= 16 && b.capacity() >= 16);
        // once drained, the pool falls back to reclaim-or-allocate
        ret.put(a);
        let c = pool.take(8);
        assert_eq!((pool.hits, pool.misses), (3, 0));
        drop(c);
        let _d = pool.take(8);
        assert_eq!(pool.misses, 1);
    }

    #[test]
    fn grows_capacity_on_demand() {
        let (mut pool, ret) = slab_pair();
        ret.put(Vec::with_capacity(2));
        let v = pool.take(64);
        assert!(v.capacity() >= 64, "reserve must honor the larger request");
        assert_eq!(pool.hits, 1);
    }
}
