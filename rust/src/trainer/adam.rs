//! Fused Adam optimizer (host-side, fp32).
//!
//! The paper trains with an fp16 Adam keeping fp32 master weights and
//! moments (18 B/param, §4.1); on CPU-PJRT everything is already fp32, so
//! the optimizer is a straightforward fused loop per parameter tensor.
//! Lives in L3 (not HLO) because each stage's parameters are a ragged list
//! of differently-shaped tensors — shape-monomorphic HLO would need one
//! artifact per shape for no benefit at this scale.

use anyhow::Result;

use crate::runtime::Tensor;

/// Adam with bias correction (Kingma & Ba), β = (0.9, 0.95) like the paper.
#[derive(Debug)]
pub struct Adam {
    /// Learning rate (mutable: the trainer applies LR warmup per step).
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
    /// Completed update count (drives bias correction).
    pub step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    /// Fresh optimizer state shaped like `params`.
    pub fn new(lr: f32, params: &[Tensor]) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.95, // the paper's β2 (§4.2)
            eps: 1e-8,
            step: 0,
            m: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            v: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
        }
    }

    /// Apply one update in place. `grads[i]` must match `params[i]`'s shape.
    pub fn update(&mut self, params: &mut [Tensor], grads: &[Tensor]) -> Result<()> {
        self.fused_update(params, grads, 1.0)
    }

    /// One optimizer step with the gradient multiplier folded into the
    /// sweep: `p -= adam(g * gscale)`.
    ///
    /// `gscale` carries both the microbatch mean (1/m) and the grad-clip
    /// factor, so the trainer's old three passes over every gradient
    /// (scale by 1/m, scale by the clip ratio, then the Adam read) collapse
    /// into this single pass — and the gradients themselves are left
    /// untouched, which is what lets the trainer recycle them as slabs.
    /// `fused_update(.., k)` is bitwise identical to scaling the grads by
    /// `k` in place and then calling `update` (same f32 operation order).
    pub fn fused_update(
        &mut self,
        params: &mut [Tensor],
        grads: &[Tensor],
        gscale: f32,
    ) -> Result<()> {
        assert_eq!(params.len(), grads.len());
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let lr_t = self.lr * bc2.sqrt() / bc1;
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let g = g.as_f32()?;
            let p = p.as_f32_mut()?;
            debug_assert_eq!(p.len(), g.len());
            // fused loop: single pass over the four arrays, scale applied
            // on the fly
            for i in 0..p.len() {
                let gi = g[i] * gscale;
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gi;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gi * gi;
                p[i] -= lr_t * m[i] / (v[i].sqrt() + self.eps);
            }
        }
        Ok(())
    }
}

/// Global L2 norm over a gradient list, as one read-only pass (no
/// intermediate scaling writes). `||k·g|| == k·||g||`, so callers clip
/// against `scale * global_grad_norm(raw)` instead of materializing the
/// scaled gradients first.
pub fn global_grad_norm(grads: &[Tensor]) -> Result<f32> {
    let mut sumsq = 0.0f32;
    for g in grads {
        for x in g.as_f32()? {
            sumsq += x * x;
        }
    }
    Ok(sumsq.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(params: &[Tensor]) -> Vec<Tensor> {
        // grad of f(x) = 0.5 * ||x - 3||^2  =>  x - 3
        params
            .iter()
            .map(|p| {
                let g: Vec<f32> = p.as_f32().unwrap().iter().map(|x| x - 3.0).collect();
                Tensor::f32(g, p.shape.clone())
            })
            .collect()
    }

    #[test]
    fn converges_on_quadratic() {
        let mut params = vec![Tensor::f32(vec![0.0, 10.0, -5.0], vec![3])];
        let mut opt = Adam::new(0.1, &params);
        for _ in 0..500 {
            let g = quad_grad(&params);
            opt.update(&mut params, &g).unwrap();
        }
        for x in params[0].as_f32().unwrap() {
            assert!((x - 3.0).abs() < 0.05, "x = {x}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        // with bias correction, |Δ| ≈ lr on step 1 regardless of grad scale
        let mut params = vec![Tensor::f32(vec![0.0], vec![1])];
        let mut opt = Adam::new(0.01, &params);
        let g = vec![Tensor::f32(vec![123.0], vec![1])];
        opt.update(&mut params, &g).unwrap();
        let moved = params[0].as_f32().unwrap()[0].abs();
        assert!((moved - 0.01).abs() < 1e-3, "moved {moved}");
    }

    #[test]
    fn zero_grad_keeps_params() {
        let mut params = vec![Tensor::f32(vec![1.0, 2.0], vec![2])];
        let mut opt = Adam::new(0.1, &params);
        let g = vec![Tensor::zeros(vec![2])];
        opt.update(&mut params, &g).unwrap();
        assert_eq!(params[0].as_f32().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn fused_scale_matches_prescaled_grads_bitwise() {
        // fused_update(.., k) must equal scale-then-update exactly — this
        // is the trainer's clip+mean fold
        let init = vec![
            Tensor::f32(vec![0.3, -1.2, 7.0], vec![3]),
            Tensor::f32(vec![2.0, -2.0], vec![2]),
        ];
        let grads = vec![
            Tensor::f32(vec![0.5, -0.25, 3.0], vec![3]),
            Tensor::f32(vec![-1.5, 0.75], vec![2]),
        ];
        let k = 0.125f32;

        let mut fused_p = init.clone();
        let mut fused_opt = Adam::new(0.01, &fused_p);
        for _ in 0..5 {
            fused_opt.fused_update(&mut fused_p, &grads, k).unwrap();
        }

        let mut ref_p = init;
        let mut ref_opt = Adam::new(0.01, &ref_p);
        let mut scaled = grads;
        for g in &mut scaled {
            g.scale(k).unwrap();
        }
        for _ in 0..5 {
            ref_opt.update(&mut ref_p, &scaled).unwrap();
        }

        for (a, b) in fused_p.iter().zip(&ref_p) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn global_grad_norm_is_l2_over_all_tensors() {
        let grads = vec![
            Tensor::f32(vec![3.0], vec![1]),
            Tensor::f32(vec![4.0], vec![1]),
        ];
        assert!((global_grad_norm(&grads).unwrap() - 5.0).abs() < 1e-6);
        assert_eq!(global_grad_norm(&[]).unwrap(), 0.0);
    }
}
