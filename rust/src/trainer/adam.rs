//! Fused Adam optimizer (host-side, fp32).
//!
//! The paper trains with an fp16 Adam keeping fp32 master weights and
//! moments (18 B/param, §4.1); on CPU-PJRT everything is already fp32, so
//! the optimizer is a straightforward fused loop per parameter tensor.
//! Lives in L3 (not HLO) because each stage's parameters are a ragged list
//! of differently-shaped tensors — shape-monomorphic HLO would need one
//! artifact per shape for no benefit at this scale.

use anyhow::Result;

use crate::runtime::Tensor;

/// Adam with bias correction (Kingma & Ba), β = (0.9, 0.95) like the paper.
#[derive(Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32, params: &[Tensor]) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.95, // the paper's β2 (§4.2)
            eps: 1e-8,
            step: 0,
            m: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            v: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
        }
    }

    /// Apply one update in place. `grads[i]` must match `params[i]`'s shape.
    pub fn update(&mut self, params: &mut [Tensor], grads: &[Tensor]) -> Result<()> {
        assert_eq!(params.len(), grads.len());
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let lr_t = self.lr * bc2.sqrt() / bc1;
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let g = g.as_f32()?;
            let p = p.as_f32_mut()?;
            debug_assert_eq!(p.len(), g.len());
            // fused loop: single pass over the four arrays
            for i in 0..p.len() {
                let gi = g[i];
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gi;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gi * gi;
                p[i] -= lr_t * m[i] / (v[i].sqrt() + self.eps);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(params: &[Tensor]) -> Vec<Tensor> {
        // grad of f(x) = 0.5 * ||x - 3||^2  =>  x - 3
        params
            .iter()
            .map(|p| {
                let g: Vec<f32> = p.as_f32().unwrap().iter().map(|x| x - 3.0).collect();
                Tensor::f32(g, p.shape.clone())
            })
            .collect()
    }

    #[test]
    fn converges_on_quadratic() {
        let mut params = vec![Tensor::f32(vec![0.0, 10.0, -5.0], vec![3])];
        let mut opt = Adam::new(0.1, &params);
        for _ in 0..500 {
            let g = quad_grad(&params);
            opt.update(&mut params, &g).unwrap();
        }
        for x in params[0].as_f32().unwrap() {
            assert!((x - 3.0).abs() < 0.05, "x = {x}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        // with bias correction, |Δ| ≈ lr on step 1 regardless of grad scale
        let mut params = vec![Tensor::f32(vec![0.0], vec![1])];
        let mut opt = Adam::new(0.01, &params);
        let g = vec![Tensor::f32(vec![123.0], vec![1])];
        opt.update(&mut params, &g).unwrap();
        let moved = params[0].as_f32().unwrap()[0].abs();
        assert!((moved - 0.01).abs() < 1e-3, "moved {moved}");
    }

    #[test]
    fn zero_grad_keeps_params() {
        let mut params = vec![Tensor::f32(vec![1.0, 2.0], vec![2])];
        let mut opt = Adam::new(0.1, &params);
        let g = vec![Tensor::zeros(vec![2])];
        opt.update(&mut params, &g).unwrap();
        assert_eq!(params[0].as_f32().unwrap(), &[1.0, 2.0]);
    }
}
