//! Fused Adam optimizer (host-side, fp32) and its ZeRO-style sharded form.
//!
//! The paper trains with an fp16 Adam keeping fp32 master weights and
//! moments (18 B/param, §4.1); on CPU-PJRT everything is already fp32, so
//! the optimizer is a straightforward fused loop per parameter tensor.
//! Lives in L3 (not HLO) because each stage's parameters are a ragged list
//! of differently-shaped tensors — shape-monomorphic HLO would need one
//! artifact per shape for no benefit at this scale.
//!
//! ## Sharded state ([`ShardedAdam`], docs/hotpath.md §Sharded optimizer)
//!
//! Adam is elementwise, so its state partitions freely: rank r of an
//! n-rank group keeps moments only for the contiguous flat element range
//! [`crate::comm::collectives::segment`]`(r, numel, n)` of its (stage,
//! chunk)'s parameters — exactly the shard the chunked all-reduce's
//! reduce-scatter phase already produces. One data-parallel step
//! ([`sharded_group_step`]) is then reduce-scatter the gradients → Adam on
//! the owned shard → all-gather the updated parameters, and is **bitwise**
//! identical to summing the gradients with `all_reduce_as` and running the
//! monolithic [`Adam::fused_update`] on every rank (property-tested
//! below): the per-element summation order and the per-element update
//! arithmetic are unchanged, only their location moves. At n = 1 (the live
//! trainer's current group size per stage) the shard is the whole chunk
//! and the update degenerates to the plain fused sweep, bitwise.

use anyhow::{ensure, Result};
use std::sync::Arc;

use crate::comm::collectives::segment;
use crate::comm::{AllReduceGroup, DpSyncGroup};
use crate::runtime::Tensor;

/// Adam with bias correction (Kingma & Ba), β = (0.9, 0.95) like the paper.
#[derive(Debug)]
pub struct Adam {
    /// Learning rate (mutable: the trainer applies LR warmup per step).
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
    /// Completed update count (drives bias correction).
    pub step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    /// Fresh optimizer state shaped like `params`.
    pub fn new(lr: f32, params: &[Tensor]) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.95, // the paper's β2 (§4.2)
            eps: 1e-8,
            step: 0,
            m: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            v: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
        }
    }

    /// Apply one update in place. `grads[i]` must match `params[i]`'s shape.
    pub fn update(&mut self, params: &mut [Tensor], grads: &[Tensor]) -> Result<()> {
        self.fused_update(params, grads, 1.0)
    }

    /// One optimizer step with the gradient multiplier folded into the
    /// sweep: `p -= adam(g * gscale)`.
    ///
    /// `gscale` carries both the microbatch mean (1/m) and the grad-clip
    /// factor, so the trainer's old three passes over every gradient
    /// (scale by 1/m, scale by the clip ratio, then the Adam read) collapse
    /// into this single pass — and the gradients themselves are left
    /// untouched, which is what lets the trainer recycle them as slabs.
    /// `fused_update(.., k)` is bitwise identical to scaling the grads by
    /// `k` in place and then calling `update` (same f32 operation order).
    pub fn fused_update(
        &mut self,
        params: &mut [Tensor],
        grads: &[Tensor],
        gscale: f32,
    ) -> Result<()> {
        assert_eq!(params.len(), grads.len());
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let lr_t = self.lr * bc2.sqrt() / bc1;
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let g = g.as_f32()?;
            let p = p.as_f32_mut()?;
            debug_assert_eq!(p.len(), g.len());
            // fused loop: single pass over the four arrays, scale applied
            // on the fly
            for i in 0..p.len() {
                adam_elem(
                    &mut m[i], &mut v[i], &mut p[i],
                    g[i] * gscale,
                    self.beta1, self.beta2, lr_t, self.eps,
                );
            }
        }
        Ok(())
    }
}

/// The single definition of Adam's per-element update — every sweep in
/// this module (monolithic [`Adam::fused_update`] and both sharded paths)
/// funnels through it, so their bitwise agreement is structural, not a
/// convention to maintain across copies.
#[inline]
#[allow(clippy::too_many_arguments)]
fn adam_elem(
    m: &mut f32,
    v: &mut f32,
    p: &mut f32,
    gi: f32,
    b1: f32,
    b2: f32,
    lr_t: f32,
    eps: f32,
) {
    *m = b1 * *m + (1.0 - b1) * gi;
    *v = b2 * *v + (1.0 - b2) * gi * gi;
    *p -= lr_t * *m / (v.sqrt() + eps);
}

/// Global L2 norm over a gradient list, as one read-only pass (no
/// intermediate scaling writes). `||k·g|| == k·||g||`, so callers clip
/// against `scale * global_grad_norm(raw)` instead of materializing the
/// scaled gradients first.
pub fn global_grad_norm(grads: &[Tensor]) -> Result<f32> {
    let mut sumsq = 0.0f32;
    for g in grads {
        for x in g.as_f32()? {
            sumsq += x * x;
        }
    }
    Ok(sumsq.sqrt())
}

/// Per-segment sums of squares over the **flat concatenation** of a ragged
/// gradient list, split into `nseg` contiguous
/// [`segment`]`(r, total, nseg)` ranges — the same sharding contract the
/// collectives and [`ShardedAdam`] use.
///
/// This is the dp trainer's *canonical clip-norm decomposition*: rank r of
/// a dp group computes `segmented_sumsq`-segment r locally from its
/// reduce-scattered gradient shard, the per-(chunk, rank) partials are
/// exchanged as scalars, and every rank combines them in the same fixed
/// order — so the resulting norm (and therefore the clip factor) is
/// bitwise identical on every rank, and to a single-process reference that
/// calls this function on the full summed gradient. Each partial is
/// accumulated left-to-right from 0.0 in f32, exactly like a rank's local
/// loop over its shard.
pub fn segmented_sumsq(grads: &[Tensor], nseg: usize) -> Result<Vec<f32>> {
    let total: usize = grads.iter().map(Tensor::numel).sum();
    (0..nseg)
        .map(|r| {
            let (lo, hi) = segment(r, total, nseg);
            masked_range_sumsq(grads, lo, hi, None)
        })
        .collect()
}

/// Clip `windows` (ascending, disjoint) to `[lo, hi)`, in order. `None`
/// means "everything": the single window `[lo, hi)`.
fn clipped_windows(
    lo: usize,
    hi: usize,
    mask: Option<&[std::ops::Range<usize>]>,
) -> Vec<std::ops::Range<usize>> {
    match mask {
        None => vec![lo..hi],
        Some(ranges) => ranges
            .iter()
            .map(|m| m.start.max(lo)..m.end.min(hi))
            .filter(|w| w.start < w.end)
            .collect(),
    }
}

/// Sum of squares over the flat element range `[lo, hi)` of a ragged
/// gradient list, optionally restricted to `mask` (ascending flat ranges —
/// the tp trainer's [`crate::runtime::TpStageView::local_elem_ranges`]).
/// One f32 accumulator from 0.0, elements visited in ascending flat order —
/// the same walk as [`masked_seg_sumsq`], so a reference that reads ragged
/// accumulated gradients and a live rank that reads its reduce-scattered
/// flat segment produce the same bits.
///
/// This is the tp extension of the canonical clip-norm decomposition: tp
/// rank 0 contributes the whole (chunk, dp-segment) window, ranks > 0 only
/// their expert-local elements (their replicated/summed gradients are
/// bitwise rank 0's and must be counted exactly once in the stage norm).
pub fn masked_range_sumsq(
    grads: &[Tensor],
    lo: usize,
    hi: usize,
    mask: Option<&[std::ops::Range<usize>]>,
) -> Result<f32> {
    let sizes: Vec<usize> = grads.iter().map(Tensor::numel).collect();
    let mut acc = 0.0f32;
    for w in clipped_windows(lo, hi, mask) {
        for (ti, r) in flat_slices(&sizes, w.start, w.end) {
            for x in &grads[ti].as_f32()?[r] {
                acc += x * x;
            }
        }
    }
    Ok(acc)
}

/// [`masked_range_sumsq`] over a flat slice `seg` covering the flat
/// element range `[seg_lo, seg_lo + seg.len())` — the live dp path, whose
/// per-rank gradient exists only as the reduce-scattered segment.
pub fn masked_seg_sumsq(
    seg: &[f32],
    seg_lo: usize,
    mask: Option<&[std::ops::Range<usize>]>,
) -> f32 {
    let mut acc = 0.0f32;
    for w in clipped_windows(seg_lo, seg_lo + seg.len(), mask) {
        for x in &seg[w.start - seg_lo..w.end - seg_lo] {
            acc += x * x;
        }
    }
    acc
}

/// Map a flat element range `[lo, hi)` onto a ragged tensor list: yields
/// `(tensor_index, within-tensor element range)` covering exactly the
/// overlap of `[lo, hi)` with each tensor's flat span, in order.
fn flat_slices(sizes: &[usize], lo: usize, hi: usize) -> Vec<(usize, std::ops::Range<usize>)> {
    let mut out = Vec::new();
    let mut base = 0usize;
    for (i, &n) in sizes.iter().enumerate() {
        let t_lo = lo.max(base);
        let t_hi = hi.min(base + n);
        if t_lo < t_hi {
            out.push((i, (t_lo - base)..(t_hi - base)));
        }
        base += n;
    }
    out
}

/// Where a sharded sweep reads its gradient elements from.
#[derive(Clone, Copy)]
enum GradSrc<'a> {
    /// The trainer path: the chunk's ragged accumulated-gradient tensors.
    Ragged(&'a [Tensor]),
    /// The group path: this rank's flat reduce-scatter output.
    Flat(&'a [f32]),
}

/// Adam whose state covers one contiguous **shard** of a flat parameter
/// space — rank `r` of `n` owns [`segment`]`(r, numel, n)` of the (stage,
/// chunk)'s concatenated parameters and keeps moments only for it
/// (`8 B/param / n` instead of `8 B/param` replicated).
///
/// With `nranks = 1` the shard is the whole space and
/// [`ShardedAdam::update_shard`] is **bitwise** identical to
/// [`Adam::fused_update`] over the same tensors (same per-element f32
/// operation order) — the live trainer's per-(stage, chunk) path. With
/// `nranks > 1`, [`sharded_group_step`] drives the full data-parallel
/// reduce-scatter → shard update → all-gather round.
#[derive(Debug)]
pub struct ShardedAdam {
    /// Learning rate (mutable: the trainer applies LR warmup per step).
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
    /// Completed update count (drives bias correction; checkpointed).
    pub step: u64,
    rank: usize,
    nranks: usize,
    /// Per-tensor element counts of the full (chunk) parameter list.
    sizes: Vec<usize>,
    /// Owned flat range: `segment(rank, total, nranks)`.
    lo: usize,
    hi: usize,
    /// First/second moments for the owned shard only (`hi - lo` elements).
    m: Vec<f32>,
    v: Vec<f32>,
}

impl ShardedAdam {
    /// Fresh sharded state for rank `rank` of `nranks` over `params`
    /// (the full chunk parameter list — every rank passes the same list).
    pub fn new(lr: f32, params: &[Tensor], rank: usize, nranks: usize) -> ShardedAdam {
        assert!(nranks > 0 && rank < nranks, "rank {rank} of {nranks}");
        let sizes: Vec<usize> = params.iter().map(Tensor::numel).collect();
        let total: usize = sizes.iter().sum();
        let (lo, hi) = segment(rank, total, nranks);
        ShardedAdam {
            lr,
            beta1: 0.9,
            beta2: 0.95, // the paper's β2 (§4.2)
            eps: 1e-8,
            step: 0,
            rank,
            nranks,
            sizes,
            lo,
            hi,
            m: vec![0.0; hi - lo],
            v: vec![0.0; hi - lo],
        }
    }

    /// This shard's rank within its group.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Group size the parameter space is sharded across.
    pub fn nranks(&self) -> usize {
        self.nranks
    }

    /// Flat element count of the full (unsharded) parameter space.
    pub fn total(&self) -> usize {
        self.sizes.iter().sum()
    }

    /// The owned flat element range (`segment(rank, total, nranks)`).
    pub fn owned(&self) -> std::ops::Range<usize> {
        self.lo..self.hi
    }

    /// Checkpoint view: (step, first moments, second moments) of the shard.
    pub fn state(&self) -> (u64, &[f32], &[f32]) {
        (self.step, &self.m, &self.v)
    }

    /// Restore checkpointed shard state (shapes must match this shard).
    pub fn restore_state(&mut self, step: u64, m: &[f32], v: &[f32]) -> Result<()> {
        ensure!(
            m.len() == self.m.len() && v.len() == self.v.len(),
            "optimizer shard mismatch: {} moments vs owned range {}..{}",
            m.len(),
            self.lo,
            self.hi
        );
        self.step = step;
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
        Ok(())
    }

    fn check_tensors(&self, params: &[Tensor]) -> Result<()> {
        ensure!(
            params.len() == self.sizes.len(),
            "sharded Adam built over {} tensors, given {}",
            self.sizes.len(),
            params.len()
        );
        for (p, &n) in params.iter().zip(&self.sizes) {
            ensure!(p.numel() == n, "parameter tensor size changed: {} vs {n}", p.numel());
        }
        Ok(())
    }

    /// One optimizer step over the owned shard, reading gradients from the
    /// full ragged `grads` list (the trainer's `grad_acc` sub-slice).
    /// Elements outside the shard are untouched. Bitwise identical to
    /// [`Adam::fused_update`] restricted to the shard's elements.
    pub fn update_shard(
        &mut self,
        params: &mut [Tensor],
        grads: &[Tensor],
        gscale: f32,
    ) -> Result<()> {
        ensure!(grads.len() == params.len(), "params/grads length mismatch");
        self.sweep(params, GradSrc::Ragged(grads), gscale)
    }

    /// One optimizer step over the owned shard, reading gradients from a
    /// **flat** shard-sized slice — the reduce-scatter output of
    /// [`crate::comm::AllReduceGroup::reduce_scatter_as`].
    pub fn update_flat(
        &mut self,
        params: &mut [Tensor],
        gshard: &[f32],
        gscale: f32,
    ) -> Result<()> {
        ensure!(
            gshard.len() == self.hi - self.lo,
            "flat gradient shard: {} elements vs owned {}..{}",
            gshard.len(),
            self.lo,
            self.hi
        );
        self.sweep(params, GradSrc::Flat(gshard), gscale)
    }

    /// The one sharded sweep both update entry points dispatch to: walk the
    /// owned flat range over the ragged tensors, applying [`adam_elem`] per
    /// element. `GradSrc` only decides where a gradient element is read
    /// from — the arithmetic and its order exist once.
    fn sweep(&mut self, params: &mut [Tensor], grads: GradSrc<'_>, gscale: f32) -> Result<()> {
        self.check_tensors(params)?;
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let lr_t = self.lr * bc2.sqrt() / bc1;
        let mut off = 0usize; // offset into the shard-local moment arrays
        for (ti, r) in flat_slices(&self.sizes, self.lo, self.hi) {
            // pick this segment's gradient slice once; the inner loop is
            // dispatch-free either way
            let gseg: &[f32] = match grads {
                GradSrc::Ragged(gs) => &gs[ti].as_f32()?[r.clone()],
                GradSrc::Flat(flat) => &flat[off..off + r.len()],
            };
            let p = params[ti].as_f32_mut()?;
            for (k, i) in r.clone().enumerate() {
                let j = off + k;
                adam_elem(
                    &mut self.m[j], &mut self.v[j], &mut p[i],
                    gseg[k] * gscale,
                    self.beta1, self.beta2, lr_t, self.eps,
                );
            }
            off += r.len();
        }
        Ok(())
    }

    /// Copy the owned parameter shard into `out` (cleared first) — the
    /// all-gather deposit of [`sharded_group_step`].
    pub fn flatten_owned(&self, params: &[Tensor], out: &mut Vec<f32>) -> Result<()> {
        self.check_tensors(params)?;
        out.clear();
        out.reserve(self.hi - self.lo);
        for (ti, r) in flat_slices(&self.sizes, self.lo, self.hi) {
            out.extend_from_slice(&params[ti].as_f32()?[r]);
        }
        Ok(())
    }

    /// Write a full flat parameter vector (the all-gather result) back into
    /// the ragged tensor list.
    pub fn scatter_full(&self, params: &mut [Tensor], full: &[f32]) -> Result<()> {
        self.check_tensors(params)?;
        ensure!(
            full.len() == self.total(),
            "gathered {} elements vs {} parameters",
            full.len(),
            self.total()
        );
        let mut base = 0usize;
        for p in params.iter_mut() {
            let dst = p.as_f32_mut()?;
            dst.copy_from_slice(&full[base..base + dst.len()]);
            base += dst.len();
        }
        Ok(())
    }
}

/// One data-parallel **sharded optimizer step** over an
/// [`AllReduceGroup`]: reduce-scatter this rank's local gradient
/// contribution (rank-order per-element sums — bitwise the all-reduce
/// result), run Adam on the owned parameter shard only, then all-gather
/// every rank's updated shard so all replicas hold the new parameters.
///
/// Bitwise equivalent to `all_reduce_as` + [`Adam::fused_update`] on every
/// rank, while each rank stores 1/n of the moments and never materializes
/// the full summed gradient (property-tested below). Call from exactly `n`
/// threads per step, like the underlying collective.
pub fn sharded_group_step(
    opt: &mut ShardedAdam,
    group: &Arc<AllReduceGroup>,
    params: &mut [Tensor],
    grads: &[Tensor],
    gscale: f32,
) -> Result<()> {
    sharded_group_step_with(opt, group, params, grads, gscale, &mut GroupStepScratch::new())
}

/// Reusable buffers for [`sharded_group_step_with`]: round-trip one scratch
/// per (optimizer, group) across steps and the steady-state sync path
/// performs **zero heap allocations** — every vector's capacity converges
/// after the first step and is thereafter refilled in place (the bench's
/// `optimizer/zero1-live` rows assert pointer/capacity stability).
#[derive(Debug, Default)]
pub struct GroupStepScratch {
    /// Flattened local gradient contribution (`total` elements).
    pub flat: Vec<f32>,
    /// This rank's reduce-scattered summed gradient segment.
    pub seg: Vec<f32>,
    /// This rank's updated parameter shard (all-gather deposit).
    pub shard: Vec<f32>,
}

impl GroupStepScratch {
    /// Empty scratch; buffers grow to steady-state capacity on first use.
    pub fn new() -> GroupStepScratch {
        GroupStepScratch::default()
    }
}

/// [`sharded_group_step`] with caller-owned scratch buffers: the same
/// reduce-scatter → shard-Adam → all-gather round (bitwise identical — the
/// collective is [`AllReduceGroup::reduce_scatter_into`], property-tested
/// against the allocating variant), but allocation-free in steady state.
pub fn sharded_group_step_with(
    opt: &mut ShardedAdam,
    group: &Arc<AllReduceGroup>,
    params: &mut [Tensor],
    grads: &[Tensor],
    gscale: f32,
    scratch: &mut GroupStepScratch,
) -> Result<()> {
    ensure!(
        group.ranks() == opt.nranks(),
        "group of {} ranks vs optimizer sharded {} ways",
        group.ranks(),
        opt.nranks()
    );
    // flatten this rank's local (unsummed) gradient contribution into the
    // reused buffer
    flatten_grads(grads, &mut scratch.flat)?;
    ensure!(
        scratch.flat.len() == opt.total(),
        "gradients: {} elements vs {} parameters",
        scratch.flat.len(),
        opt.total()
    );
    group.reduce_scatter_into(opt.rank(), &scratch.flat, &mut scratch.seg);
    opt.update_flat(params, &scratch.seg, gscale)?;
    gather_updated_params(
        opt,
        &DpSyncGroup::Flat(group.clone()),
        params,
        &mut scratch.shard,
    )
}

/// Flatten a ragged gradient list into `out` (cleared first, capacity
/// reused) in tensor order — the single definition of a group round's
/// contribution layout, shared by [`sharded_group_step_with`] and the live
/// trainer's bucket hook. The concatenation order is load-bearing for the
/// bitwise dp-equivalence contract: it must match the flat element space
/// [`ShardedAdam`] shards by [`segment`].
pub fn flatten_grads(grads: &[Tensor], out: &mut Vec<f32>) -> Result<()> {
    out.clear();
    for g in grads {
        out.extend_from_slice(g.as_f32()?);
    }
    Ok(())
}

/// Broadcast a rank's freshly-updated parameter shard to its group:
/// flatten the owned shard into the reused `gather_buf`, all-gather every
/// rank's segment, and write the slot-order concatenation back into the
/// ragged tensors. This is the single definition of the group step's
/// gather tail — shared by [`sharded_group_step_with`] and the live
/// trainer's per-chunk ZeRO-1 update, so the broadcast arithmetic can
/// never drift between them. Must be called inside the round opened by the
/// matching reduce-scatter phase.
pub fn gather_updated_params(
    opt: &ShardedAdam,
    group: &DpSyncGroup,
    params: &mut [Tensor],
    gather_buf: &mut Vec<f32>,
) -> Result<()> {
    opt.flatten_owned(params, gather_buf)?;
    let full = group.all_gather_as(opt.rank(), gather_buf);
    opt.scatter_full(params, &full)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(params: &[Tensor]) -> Vec<Tensor> {
        // grad of f(x) = 0.5 * ||x - 3||^2  =>  x - 3
        params
            .iter()
            .map(|p| {
                let g: Vec<f32> = p.as_f32().unwrap().iter().map(|x| x - 3.0).collect();
                Tensor::f32(g, p.shape.clone())
            })
            .collect()
    }

    #[test]
    fn converges_on_quadratic() {
        let mut params = vec![Tensor::f32(vec![0.0, 10.0, -5.0], vec![3])];
        let mut opt = Adam::new(0.1, &params);
        for _ in 0..500 {
            let g = quad_grad(&params);
            opt.update(&mut params, &g).unwrap();
        }
        for x in params[0].as_f32().unwrap() {
            assert!((x - 3.0).abs() < 0.05, "x = {x}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        // with bias correction, |Δ| ≈ lr on step 1 regardless of grad scale
        let mut params = vec![Tensor::f32(vec![0.0], vec![1])];
        let mut opt = Adam::new(0.01, &params);
        let g = vec![Tensor::f32(vec![123.0], vec![1])];
        opt.update(&mut params, &g).unwrap();
        let moved = params[0].as_f32().unwrap()[0].abs();
        assert!((moved - 0.01).abs() < 1e-3, "moved {moved}");
    }

    #[test]
    fn zero_grad_keeps_params() {
        let mut params = vec![Tensor::f32(vec![1.0, 2.0], vec![2])];
        let mut opt = Adam::new(0.1, &params);
        let g = vec![Tensor::zeros(vec![2])];
        opt.update(&mut params, &g).unwrap();
        assert_eq!(params[0].as_f32().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn fused_scale_matches_prescaled_grads_bitwise() {
        // fused_update(.., k) must equal scale-then-update exactly — this
        // is the trainer's clip+mean fold
        let init = vec![
            Tensor::f32(vec![0.3, -1.2, 7.0], vec![3]),
            Tensor::f32(vec![2.0, -2.0], vec![2]),
        ];
        let grads = vec![
            Tensor::f32(vec![0.5, -0.25, 3.0], vec![3]),
            Tensor::f32(vec![-1.5, 0.75], vec![2]),
        ];
        let k = 0.125f32;

        let mut fused_p = init.clone();
        let mut fused_opt = Adam::new(0.01, &fused_p);
        for _ in 0..5 {
            fused_opt.fused_update(&mut fused_p, &grads, k).unwrap();
        }

        let mut ref_p = init;
        let mut ref_opt = Adam::new(0.01, &ref_p);
        let mut scaled = grads;
        for g in &mut scaled {
            g.scale(k).unwrap();
        }
        for _ in 0..5 {
            ref_opt.update(&mut ref_p, &scaled).unwrap();
        }

        for (a, b) in fused_p.iter().zip(&ref_p) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn global_grad_norm_is_l2_over_all_tensors() {
        let grads = vec![
            Tensor::f32(vec![3.0], vec![1]),
            Tensor::f32(vec![4.0], vec![1]),
        ];
        assert!((global_grad_norm(&grads).unwrap() - 5.0).abs() < 1e-6);
        assert_eq!(global_grad_norm(&[]).unwrap(), 0.0);
    }

    // ---------------- sharded Adam ----------------

    use crate::comm::{Algo, AllReduceGroup};
    use crate::util::prop::forall;

    #[test]
    fn flat_slices_partition_ragged_lists() {
        // [3, 0, 4, 2] flat space of 9 elements
        let sizes = [3usize, 0, 4, 2];
        assert_eq!(flat_slices(&sizes, 0, 9), vec![(0, 0..3), (2, 0..4), (3, 0..2)]);
        assert_eq!(flat_slices(&sizes, 2, 5), vec![(0, 2..3), (2, 0..2)]);
        assert_eq!(flat_slices(&sizes, 7, 9), vec![(3, 0..2)]);
        assert_eq!(flat_slices(&sizes, 4, 4), vec![]);
    }

    fn rand_tensors(rng: &mut crate::util::prng::Rng, n: usize, max_elems: usize) -> Vec<Tensor> {
        (0..n)
            .map(|_| {
                let k = rng.below(max_elems + 1);
                let data: Vec<f32> = (0..k).map(|_| rng.f32() * 2.0 - 1.0).collect();
                Tensor::f32(data, vec![k])
            })
            .collect()
    }

    #[test]
    fn single_rank_shard_is_bitwise_fused_update() {
        // nranks = 1: the live trainer's per-chunk path must reproduce the
        // monolithic fused sweep exactly, including with a fold-in gscale
        let mut rng = crate::util::prng::Rng::new(5);
        let init = rand_tensors(&mut rng, 3, 40);
        let mut mono_p = init.clone();
        let mut mono = Adam::new(0.02, &mono_p);
        let mut shard_p = init;
        let mut shard = ShardedAdam::new(0.02, &shard_p, 0, 1);
        for step in 0..5 {
            let grads = rand_tensors(&mut rng, 3, 40);
            // re-size grads to match params (rand_tensors draws fresh sizes)
            let grads: Vec<Tensor> = mono_p
                .iter()
                .zip(&grads)
                .map(|(p, g)| {
                    let mut d = g.as_f32().unwrap().to_vec();
                    d.resize(p.numel(), 0.25);
                    Tensor::f32(d, p.shape.clone())
                })
                .collect();
            let gscale = 1.0 / (step + 1) as f32;
            mono.fused_update(&mut mono_p, &grads, gscale).unwrap();
            shard.update_shard(&mut shard_p, &grads, gscale).unwrap();
        }
        for (a, b) in mono_p.iter().zip(&shard_p) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn sharded_group_step_matches_monolithic_bitwise_property() {
        // THE equivalence the trainer refactor rests on: for n ∈ {1, 2, 4}
        // ranks and random ragged shapes, 5 steps of reduce-scatter →
        // shard-Adam → all-gather leave every rank's parameters BITWISE
        // equal to all-reduce-summed gradients + the legacy monolithic
        // fused_update.
        forall(
            "sharded-adam-equals-fused",
            41,
            18,
            |r| {
                let n = [1usize, 2, 4][r.below(3)];
                let ntensors = r.range(1, 4);
                let mut rng = r.split();
                let init = rand_tensors(&mut rng, ntensors, 30);
                // per-step, per-rank local gradient contributions
                let steps = 5;
                let grads: Vec<Vec<Vec<Tensor>>> = (0..steps)
                    .map(|_| {
                        (0..n)
                            .map(|_| {
                                init.iter()
                                    .map(|p| {
                                        let d: Vec<f32> = (0..p.numel())
                                            .map(|_| rng.f32() * 2.0 - 1.0)
                                            .collect();
                                        Tensor::f32(d, p.shape.clone())
                                    })
                                    .collect()
                            })
                            .collect()
                    })
                    .collect();
                let gscales: Vec<f32> =
                    (0..steps).map(|_| 0.25 + rng.f32()).collect();
                (n, init, grads, gscales)
            },
            |(n, init, grads, gscales)| {
                let n = *n;
                // ---- monolithic reference: rank-order summed grads ----
                let mut ref_p = init.clone();
                let mut ref_opt = Adam::new(0.01, &ref_p);
                for (per_rank, gscale) in grads.iter().zip(gscales) {
                    let summed: Vec<Tensor> = (0..init.len())
                        .map(|ti| {
                            let mut acc = vec![0.0f32; init[ti].numel()];
                            for rank_grads in per_rank {
                                for (a, x) in
                                    acc.iter_mut().zip(rank_grads[ti].as_f32().unwrap())
                                {
                                    *a += x;
                                }
                            }
                            Tensor::f32(acc, init[ti].shape.clone())
                        })
                        .collect();
                    ref_opt.fused_update(&mut ref_p, &summed, *gscale).unwrap();
                }
                // ---- sharded group: n threads, each a DP replica ----
                let group = AllReduceGroup::with_algo(n, Algo::Chunked);
                let mut rank_params: Vec<Vec<Tensor>> =
                    (0..n).map(|_| init.clone()).collect();
                let mut opts: Vec<ShardedAdam> =
                    (0..n).map(|r| ShardedAdam::new(0.01, init, r, n)).collect();
                std::thread::scope(|s| {
                    for (rank, (opt, params)) in
                        opts.iter_mut().zip(rank_params.iter_mut()).enumerate()
                    {
                        let group = group.clone();
                        let grads = &grads;
                        let gscales = &gscales;
                        let _ = s.spawn(move || {
                            for (per_rank, gscale) in grads.iter().zip(gscales) {
                                sharded_group_step(
                                    opt,
                                    &group,
                                    params,
                                    &per_rank[rank],
                                    *gscale,
                                )
                                .unwrap();
                            }
                        });
                    }
                });
                for (rank, params) in rank_params.iter().enumerate() {
                    for (ti, (a, b)) in params.iter().zip(&ref_p).enumerate() {
                        if a.as_f32().unwrap() != b.as_f32().unwrap() {
                            return Err(format!(
                                "rank {rank} tensor {ti} diverged from monolithic (n={n})"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn group_step_with_scratch_is_bitwise_and_alloc_stable() {
        // the scratch variant must match the allocating step bitwise, and
        // after one warmup step its buffers must never reallocate (pointer
        // + capacity stability == zero heap allocations in steady state)
        let n = 2;
        let init = vec![
            Tensor::f32(vec![0.1, -0.4, 2.0, 0.7, -1.1], vec![5]),
            Tensor::f32(vec![1.5, -0.5, 0.25], vec![3]),
        ];
        let grads: Vec<Vec<Tensor>> = (0..n)
            .map(|r| {
                init.iter()
                    .map(|p| {
                        let d: Vec<f32> =
                            (0..p.numel()).map(|i| (i as f32 + 1.0) * (r as f32 - 0.5)).collect();
                        Tensor::f32(d, p.shape.clone())
                    })
                    .collect()
            })
            .collect();
        let run = |use_scratch: bool| -> Vec<Vec<Tensor>> {
            let group = AllReduceGroup::with_algo(n, Algo::Chunked);
            let mut rank_params: Vec<Vec<Tensor>> = (0..n).map(|_| init.clone()).collect();
            let mut opts: Vec<ShardedAdam> =
                (0..n).map(|r| ShardedAdam::new(0.02, &init, r, n)).collect();
            std::thread::scope(|s| {
                for (rank, (opt, params)) in
                    opts.iter_mut().zip(rank_params.iter_mut()).enumerate()
                {
                    let group = group.clone();
                    let grads = &grads;
                    let _ = s.spawn(move || {
                        let mut scratch = GroupStepScratch::new();
                        let mut stable_ptrs = None;
                        for step in 0..6 {
                            if use_scratch {
                                sharded_group_step_with(
                                    opt, &group, params, &grads[rank], 0.5, &mut scratch,
                                )
                                .unwrap();
                                let ptrs = (
                                    scratch.flat.as_ptr(),
                                    scratch.seg.as_ptr(),
                                    scratch.shard.as_ptr(),
                                    scratch.flat.capacity(),
                                    scratch.seg.capacity(),
                                    scratch.shard.capacity(),
                                );
                                if step == 0 {
                                    stable_ptrs = Some(ptrs);
                                } else {
                                    assert_eq!(
                                        stable_ptrs,
                                        Some(ptrs),
                                        "rank {rank}: scratch reallocated after warmup"
                                    );
                                }
                            } else {
                                sharded_group_step(opt, &group, params, &grads[rank], 0.5)
                                    .unwrap();
                            }
                        }
                    });
                }
            });
            rank_params
        };
        let with_scratch = run(true);
        let reference = run(false);
        assert_eq!(with_scratch, reference);
    }

    #[test]
    fn segmented_sumsq_partitions_the_global_norm() {
        let grads = vec![
            Tensor::f32(vec![1.0, -2.0, 3.0], vec![3]),
            Tensor::f32(vec![0.5, -0.5, 4.0, 0.0], vec![4]),
        ];
        // nseg = 1: one partial, accumulated in the exact order
        // global_grad_norm walks — bitwise its square
        let one = segmented_sumsq(&grads, 1).unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].sqrt(), global_grad_norm(&grads).unwrap());
        // segments follow the collective's `segment` split of the flat
        // 7-element space: [0,3) [3,5) [5,7) at nseg = 3
        let parts = segmented_sumsq(&grads, 3).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], 1.0f32 + 4.0 + 9.0);
        assert_eq!(parts[1], 0.25f32 + 0.25);
        assert_eq!(parts[2], 16.0f32 + 0.0);
        // more segments than elements: trailing partials are empty sums
        // (plus element 6, whose value is literally 0.0)
        let many = segmented_sumsq(&grads, 9).unwrap();
        assert_eq!(many.len(), 9);
        assert_eq!(many.iter().filter(|&&x| x == 0.0).count(), 3);
    }

    #[test]
    fn masked_sumsq_ragged_and_flat_agree_bitwise() {
        // the live dp path (flat reduce-scattered segment) and the
        // emulated reference (ragged accumulated grads) must walk the same
        // elements in the same order — property over random shapes/masks
        forall(
            "masked-sumsq-paths-agree",
            53,
            40,
            |r| {
                let mut rng = r.split();
                let grads = rand_tensors(&mut rng, r.range(1, 4), 25);
                let total: usize = grads.iter().map(Tensor::numel).sum();
                // random ascending disjoint mask
                let mut mask = Vec::new();
                let mut at = 0usize;
                while at < total {
                    let lo = at + rng.below(4);
                    let hi = lo + 1 + rng.below(5);
                    if lo >= total {
                        break;
                    }
                    mask.push(lo..hi.min(total));
                    at = hi + rng.below(3);
                }
                let nseg = r.range(1, 5);
                (grads, mask, nseg)
            },
            |(grads, mask, nseg)| {
                let total: usize = grads.iter().map(Tensor::numel).sum();
                let mut flat = Vec::new();
                flatten_grads(grads, &mut flat).unwrap();
                for seg_i in 0..*nseg {
                    let (lo, hi) = segment(seg_i, total, *nseg);
                    for m in [None, Some(mask.as_slice())] {
                        let a = masked_range_sumsq(grads, lo, hi, m).unwrap();
                        let b = masked_seg_sumsq(&flat[lo..hi], lo, m);
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "seg {seg_i}/{nseg} mask={} ragged {a} vs flat {b}",
                                m.is_some()
                            ));
                        }
                    }
                }
                // unmasked over the full space == the historic fold
                let full = masked_range_sumsq(grads, 0, total, None).unwrap();
                let fold = flat.iter().fold(0.0f32, |a, x| a + x * x);
                if full.to_bits() != fold.to_bits() {
                    return Err(format!("full {full} vs fold {fold}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn masked_sumsq_counts_only_mask_elements() {
        let g = vec![Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![4])];
        // mask covers elements 1..3 -> 4 + 9
        let m = vec![1..3];
        assert_eq!(masked_range_sumsq(&g, 0, 4, Some(&m)).unwrap(), 13.0);
        // window [2, 4) clips the mask to element 2 only
        assert_eq!(masked_range_sumsq(&g, 2, 4, Some(&m)).unwrap(), 9.0);
        assert_eq!(masked_seg_sumsq(&[3.0, 4.0], 2, Some(&m)), 9.0);
        // empty intersection
        assert_eq!(masked_range_sumsq(&g, 3, 4, Some(&m)).unwrap(), 0.0);
    }

    #[test]
    fn shard_state_roundtrips_through_restore() {
        let params = vec![Tensor::f32(vec![1.0; 10], vec![10])];
        let grads = vec![Tensor::f32(vec![0.1; 10], vec![10])];
        let mut a = ShardedAdam::new(0.01, &params, 1, 3);
        let mut pa = params.clone();
        a.update_shard(&mut pa, &grads, 1.0).unwrap();
        let (step, m, v) = a.state();
        let (m, v) = (m.to_vec(), v.to_vec());
        let mut b = ShardedAdam::new(0.01, &params, 1, 3);
        b.restore_state(step, &m, &v).unwrap();
        let mut pb = pa.clone();
        let mut pa2 = pa.clone();
        a.update_shard(&mut pa2, &grads, 0.5).unwrap();
        b.update_shard(&mut pb, &grads, 0.5).unwrap();
        assert_eq!(pa2, pb);
        // wrong-rank state refuses
        let mut c = ShardedAdam::new(0.01, &params, 0, 2);
        assert!(c.restore_state(step, &m, &v).is_err());
        // owned range follows the collective's segment split
        assert_eq!(ShardedAdam::new(0.01, &params, 0, 3).owned(), 0..4);
        assert_eq!(ShardedAdam::new(0.01, &params, 1, 3).owned(), 4..7);
        assert_eq!(ShardedAdam::new(0.01, &params, 2, 3).owned(), 7..10);
    }
}
