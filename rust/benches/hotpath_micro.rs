//! Bench: L3 coordinator hot paths (the docs/hotpath.md components).
//!
//! * router dispatch (route_top1) across token/expert scales, plus the
//!   route_topk k ∈ {1, 2, 4} sweep and the tp_combine k rows (flat in k —
//!   the index-slice combine ships gate-weighted sums, not per-slot copies)
//! * in-process all-reduce: legacy single-accumulator vs chunked
//!   reduce-scatter + all-gather, across rank counts
//! * PJRT boundary: per-microbatch literal serialization vs device-resident
//!   staged-buffer reuse with pooled readback
//! * dp gradient sync: serialized step-end vs backward-overlapped bucket
//!   workers, dp ∈ {2, 4} thread groups (the `--dp`/`--no-dp-overlap` A/B)
//! * grad-clip + Adam: the old three-pass sweep vs the fused single pass;
//!   the live ZeRO-1 round with reused scratch (asserts zero steady-state
//!   allocations via pointer/capacity fingerprints)
//! * slab pool: cold fresh-alloc take vs recycled take/put round-trip,
//!   asserting the hit/miss/prefill accounting contract on the way
//! * 1F1B schedule simulation, manifest JSON parse
//!
//! Besides the human-readable lines, results are written to
//! `BENCH_hotpath.json` (component -> ns/op stats) so successive PRs can
//! diff hot-path trajectories mechanically. Before/after pairs share a
//! prefix: e.g. `all_reduce/legacy r=4` vs `all_reduce/chunked r=4`. The
//! `dp_sync/hierarchical` topology rows (flat vs two-level vs
//! chunk-pipelined at nodes ∈ {1, 2, 4}) go to their own `BENCH_comm.json`.

use std::collections::BTreeMap;
use std::sync::Arc;

use ppmoe::comm::{Algo, AllReduceGroup, DpSyncGroup, HierarchicalGroup};
use ppmoe::moe::{route_top1, route_topk, synth_logits, DropPolicy};
use ppmoe::pipeline::interleaved::{interleaved_bubble, simulate_interleaved};
use ppmoe::pipeline::{analytic_bubble, simulate, Schedule, StageTiming};
use ppmoe::runtime::Tensor;
use ppmoe::trainer::adam::{
    global_grad_norm, sharded_group_step, sharded_group_step_with, Adam, GroupStepScratch,
    ShardedAdam,
};
use ppmoe::util::bench::{bench, BenchResult};
use ppmoe::util::json::Json;
use ppmoe::util::prng::Rng;

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    println!("=== router (route_top1) ===");
    let mut rng = Rng::new(1);
    for (tokens, experts) in [(2048, 8), (16384, 64), (65536, 64)] {
        let logits = synth_logits(&mut rng, tokens, experts, 0.5);
        results.push(bench(&format!("route_top1 t={tokens} E={experts}"), || {
            route_top1(&logits, experts, tokens).tokens()
        }));
    }

    println!("\n=== router (route_topk, k sweep) ===");
    // k rounds of masked argmax over the same logits: cost should scale
    // ~linearly in k, and the k=1 row A/Bs directly against route_top1
    // above (bitwise-equal routing, so the delta is pure generalization
    // overhead). Capacity = 2·k·t/E, the default-ish factor-2 slab.
    {
        let (tokens, experts) = (16384usize, 64usize);
        let logits = synth_logits(&mut rng, tokens, experts, 0.5);
        for k in [1usize, 2, 4] {
            let capacity = 2 * k * tokens / experts;
            results.push(bench(
                &format!("route_topk t={tokens} E={experts} k={k}"),
                || route_topk(&logits, experts, capacity, k, DropPolicy::Drop).tokens(),
            ));
        }
    }

    println!("\n=== in-process all-reduce (legacy vs chunked) ===");
    let elems = 262_144; // 1 MiB of f32 per rank
    for ranks in [2usize, 4, 8] {
        for algo in [Algo::Legacy, Algo::Chunked] {
            let tag = match algo {
                Algo::Legacy => "legacy",
                Algo::Chunked => "chunked",
            };
            results.push(bench(&format!("all_reduce/{tag} r={ranks} 1MiB"), || {
                let g = AllReduceGroup::with_algo(ranks, algo);
                let handles: Vec<_> = (0..ranks)
                    .map(|r| {
                        let g: Arc<AllReduceGroup> = g.clone();
                        std::thread::spawn(move || {
                            let v = vec![r as f32; elems];
                            g.all_reduce_as(r, &v)[0]
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).sum::<f32>()
            }));
        }
    }

    println!("\n=== tp combine (live all-reduce vs serial rank-order sum) ===");
    // the inner-node combine of the live `--tp` trainer (per MoE segment,
    // forward y + backward d(hgt)) vs the emulate_tp serial reference —
    // bitwise-identical results, so the delta is pure coordination cost.
    // Sized like a tiny-config boundary activation (b·s·h = 2·32·64).
    {
        let act = 2 * 32 * 64;
        for ranks in [2usize, 4] {
            results.push(bench(&format!("tp_combine/live r={ranks} act"), || {
                let g = AllReduceGroup::with_algo(ranks, Algo::Chunked);
                let handles: Vec<_> = (0..ranks)
                    .map(|r| {
                        let g: Arc<AllReduceGroup> = g.clone();
                        std::thread::spawn(move || {
                            let v = vec![r as f32; act];
                            g.all_reduce_as(r, &v)[0]
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).sum::<f32>()
            }));
            let parts: Vec<Vec<f32>> =
                (0..ranks).map(|r| vec![r as f32; act]).collect();
            let mut out = Vec::with_capacity(act);
            results.push(bench(&format!("tp_combine/serial r={ranks} act"), || {
                let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
                ppmoe::tp::rank_order_sum_into(&refs, &mut out);
                out[0]
            }));
        }
        // the top_k sweep at fixed r=2: the combine payload is the already
        // gate-weighted b·s·h activation, so it does NOT grow with k — the
        // k rows should be flat within noise (config::tp_combine_volume's
        // k-independence claim as a measurement; a DP-MoE all-to-all would
        // scale linearly here, see config::dpmoe_a2a_volume).
        for k in [1usize, 2, 4] {
            let ranks = 2usize;
            results.push(bench(&format!("tp_combine/live k={k} act"), || {
                let g = AllReduceGroup::with_algo(ranks, Algo::Chunked);
                let handles: Vec<_> = (0..ranks)
                    .map(|r| {
                        let g: Arc<AllReduceGroup> = g.clone();
                        std::thread::spawn(move || {
                            let v = vec![(r * k) as f32; act];
                            g.all_reduce_as(r, &v)[0]
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).sum::<f32>()
            }));
            let parts: Vec<Vec<f32>> =
                (0..ranks).map(|r| vec![(r * k) as f32; act]).collect();
            let mut out = Vec::with_capacity(act);
            results.push(bench(&format!("tp_combine/serial k={k} act"), || {
                let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
                ppmoe::tp::rank_order_sum_into(&refs, &mut out);
                out[0]
            }));
        }
    }

    println!("\n=== PJRT boundary (per-micro serialize vs device-resident) ===");
    {
        let client = xla::PjRtClient::cpu().expect("stub cpu client");
        let act = Tensor::f32(vec![0.5; elems], vec![elems]);
        // before: what the pre-device-resident trainer did per microbatch —
        // serialize the host tensor to a literal on the way in, and
        // materialize a fresh Vec from the result literal on the way out
        results.push(bench("boundary/legacy_roundtrip 1MiB", || {
            let lit = act.to_literal().unwrap();
            lit.to_vec::<f32>().unwrap().len()
        }));
        // after: the input buffer was uploaded once at Fwd and stashed
        // (zero-copy at Bwd); the only boundary work left is reading the
        // outgoing payload into a recycled slab
        let staged = act.to_device(&client).unwrap();
        let mut slab: Vec<f32> = Vec::with_capacity(elems);
        results.push(bench("boundary/staged_reuse 1MiB", || {
            staged.copy_into(&mut slab).unwrap();
            slab.len()
        }));
    }

    println!("\n=== 1F1B schedule simulation ===");
    for (stages, micros) in [(4, 16), (16, 64), (64, 256)] {
        let timing = vec![StageTiming { fwd: 1.0, bwd: 2.0, p2p: 0.1 }; stages];
        results.push(bench(&format!("simulate p={stages} m={micros}"), || {
            let s = simulate(Schedule::OneFOneB, &timing, micros);
            assert!((s.bubble_fraction - analytic_bubble(stages, micros)).abs() < 0.5);
            s.makespan
        }));
    }

    println!("\n=== interleaved schedule simulation (--virtual sweep) ===");
    // the v ∈ {1, 2, 4} sweep mirrors `train_ppmoe --virtual N`: same
    // geometry, only the chunk count varies, so BENCH_hotpath.json rows
    // diff directly against each other across PRs
    for (stages, micros) in [(4usize, 16usize), (16, 64)] {
        for v in [1usize, 2, 4] {
            let timing = vec![StageTiming { fwd: 1.0, bwd: 2.0, p2p: 0.1 }; stages];
            results.push(bench(
                &format!("simulate/interleaved p={stages} m={micros} v={v}"),
                || {
                    let s = simulate_interleaved(&timing, micros, v);
                    // (p−1)/(v·m+p−1) is the zero-p2p floor on balanced
                    // stages; with p2p > 0 the event sim of the real
                    // schedule may only ever sit at or above it
                    assert!(
                        s.bubble_fraction + 1e-9 >= interleaved_bubble(stages, micros, v),
                        "simulated bubble fell below the analytic floor"
                    );
                    s.makespan
                },
            ));
        }
    }

    println!("\n=== wrap-edge transfer pipeline (overlap off vs on) ===");
    // the ring's wrap hop as a two-thread d2h → channel → h2d pipeline:
    // window = 1 serializes every hop on the consumer's upload ack (the
    // pre-overlap trainer behavior); window = 2 is the double-buffered
    // staging the trainer now runs on wrap edges — the producer's next
    // d2h proceeds while the consumer uploads the previous payload
    for elems in [65_536usize, 262_144] {
        let kib = elems * 4 / 1024;
        results.push(bench(&format!("wrap_edge/serialized {kib}KiB x8"), || {
            wrap_edge_hops(elems, 8, 1)
        }));
        results.push(bench(&format!("wrap_edge/overlapped {kib}KiB x8"), || {
            wrap_edge_hops(elems, 8, 2)
        }));
    }

    println!("\n=== dp gradient sync (serialized vs backward-overlapped) ===");
    // the trainer's `--dp` A/B, as a thread-group micro: each of dp rank
    // threads "runs a backward" producing 4 chunk buckets in sequence,
    // then reduce-scatters + all-gathers every bucket over the shared
    // per-bucket groups. Serialized = compute, then sync (--no-dp-overlap);
    // overlapped = each bucket handed to a sync worker the moment its
    // compute finishes, so the collective runs under the remaining compute
    // (the live bucket hook). Same collectives either way — only placement
    // moves, which is exactly what the row pair measures.
    for dp in [2usize, 4] {
        let elems = 65_536; // per bucket
        results.push(bench(&format!("dp_sync/serialized dp={dp}"), || {
            dp_sync_step(dp, 4, elems, false)
        }));
        results.push(bench(&format!("dp_sync/overlapped dp={dp}"), || {
            dp_sync_step(dp, 4, elems, true)
        }));
    }

    println!("\n=== dp sync topology (flat vs two-level vs chunk-pipelined) ===");
    // the live `--nodes`/`--hier-comm` A/B: one reduce-scatter + all-gather
    // round over nodes × g ranks through the flat ring vs the two-level
    // group in both forwarding modes. nodes = 1 shows the two-level
    // machinery's overhead floor (no chain); nodes > 1 adds the
    // order-preserving inter-node chain the live dp sync runs. In shared
    // memory every hop costs the same, so these rows measure coordination
    // structure, not NIC-vs-NVLink bandwidth (the cost model and
    // examples/comm_ablation.rs cover that split). Rows land in their own
    // BENCH_comm.json so the comm trajectory diffs mechanically across PRs.
    let mut comm_results: Vec<BenchResult> = Vec::new();
    {
        let elems = 65_536usize;
        let g = 2usize;
        for nodes in [1usize, 2, 4] {
            // bitwise spot check before timing (the full property sweep
            // lives in rust/tests/hier_comm.rs)
            let want = dp_sync_hier_step(nodes, g, 257, None);
            for pipelined in [false, true] {
                let got = dp_sync_hier_step(nodes, g, 257, Some(pipelined));
                assert_eq!(want.len(), got.len());
                for (a, b) in want.iter().zip(&got) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "hierarchical path diverged from flat at nodes={nodes}"
                    );
                }
            }
            comm_results.push(bench(
                &format!("dp_sync/hierarchical/flat nodes={nodes} g={g}"),
                || dp_sync_hier_step(nodes, g, elems, None)[0],
            ));
            comm_results.push(bench(
                &format!("dp_sync/hierarchical/two_level nodes={nodes} g={g}"),
                || dp_sync_hier_step(nodes, g, elems, Some(false))[0],
            ));
            comm_results.push(bench(
                &format!("dp_sync/hierarchical/pipelined nodes={nodes} g={g}"),
                || dp_sync_hier_step(nodes, g, elems, Some(true))[0],
            ));
        }
    }

    println!("\n=== grad-clip + Adam (three passes vs fused sweep) ===");
    for numel in [65_536usize, 1_048_576] {
        let grads = vec![Tensor::f32(vec![0.01; numel], vec![numel])];
        let mean = 1.0 / 4.0f32; // microbatch mean
        let max_norm = 1.0f32;
        // before: scale grads in place, norm the scaled copy, scale again
        // by the clip ratio, then the Adam pass (what the trainer did)
        let mut params = vec![Tensor::f32(vec![0.1; numel], vec![numel])];
        let mut opt = Adam::new(1e-3, &params);
        results.push(bench(&format!("optimizer/three_pass {numel}"), || {
            let mut g = grads.clone(); // the old path consumed its grads
            for t in &mut g {
                t.scale(mean).unwrap();
            }
            let norm = global_grad_norm(&g).unwrap();
            if norm > max_norm {
                let k = max_norm / norm;
                for t in &mut g {
                    t.scale(k).unwrap();
                }
            }
            opt.update(&mut params, &g).unwrap();
        }));
        // after: one read-only norm pass, then one fused sweep with the
        // mean and clip ratio folded in; grads are never copied or written
        let mut params = vec![Tensor::f32(vec![0.1; numel], vec![numel])];
        let mut opt = Adam::new(1e-3, &params);
        results.push(bench(&format!("optimizer/fused_sweep {numel}"), || {
            let norm = global_grad_norm(&grads).unwrap() * mean;
            let gscale = if norm > max_norm { mean * max_norm / norm } else { mean };
            opt.fused_update(&mut params, &grads, gscale).unwrap();
        }));
    }

    println!("\n=== sharded optimizer (reduce-scatter + shard Adam + all-gather) ===");
    // n = 1 is the live trainer's per-chunk path (bitwise the fused sweep,
    // no collective); n > 1 adds the split-phase group round while each
    // rank sweeps only 1/n of the moments
    {
        let numel = 262_144usize;
        for n in [1usize, 2, 4] {
            let mut rank_params: Vec<Vec<Tensor>> = (0..n)
                .map(|_| vec![Tensor::f32(vec![0.1; numel], vec![numel])])
                .collect();
            let grads = vec![Tensor::f32(vec![0.01; numel], vec![numel])];
            let mut opts: Vec<ShardedAdam> = (0..n)
                .map(|r| ShardedAdam::new(1e-3, &rank_params[0], r, n))
                .collect();
            let group = AllReduceGroup::with_algo(n, Algo::Chunked);
            results.push(bench(&format!("optimizer/sharded r={n} {numel}"), || {
                if n == 1 {
                    // inline, no thread fan-out: keeps the r=1 row directly
                    // comparable to optimizer/fused_sweep (same thread, the
                    // delta IS the single-rank collective round)
                    sharded_group_step(&mut opts[0], &group, &mut rank_params[0], &grads, 0.25)
                        .unwrap();
                } else {
                    std::thread::scope(|s| {
                        for (opt, params) in opts.iter_mut().zip(rank_params.iter_mut()) {
                            let group = group.clone();
                            let grads = &grads;
                            let _ = s.spawn(move || {
                                sharded_group_step(opt, &group, params, grads, 0.25).unwrap()
                            });
                        }
                    });
                }
            }));
        }
    }

    println!("\n=== live ZeRO-1 step (zero-alloc scratch, r = dp ranks) ===");
    // the trainer's steady-state optimizer round via the reused
    // GroupStepScratch: after a warmup step, every buffer's pointer and
    // capacity must be stable — the asserted "zero heap allocations in the
    // sync path" acceptance gate. r=1 compares against optimizer/sharded
    // (the delta is the scratch reuse); r>1 rows A/B against each other.
    {
        let numel = 262_144usize;
        for n in [1usize, 2, 4] {
            let mut rank_params: Vec<Vec<Tensor>> = (0..n)
                .map(|_| vec![Tensor::f32(vec![0.1; numel], vec![numel])])
                .collect();
            let grads = vec![Tensor::f32(vec![0.01; numel], vec![numel])];
            let mut opts: Vec<ShardedAdam> = (0..n)
                .map(|r| ShardedAdam::new(1e-3, &rank_params[0], r, n))
                .collect();
            let mut scratches: Vec<GroupStepScratch> =
                (0..n).map(|_| GroupStepScratch::new()).collect();
            let group = AllReduceGroup::with_algo(n, Algo::Chunked);
            // warmup: let every scratch reach steady-state capacity
            run_zero1_round(&group, &mut opts, &mut rank_params, &grads, &mut scratches);
            let fingerprints: Vec<_> = scratches.iter().map(scratch_fingerprint).collect();
            results.push(bench(&format!("optimizer/zero1-live r={n} {numel}"), || {
                run_zero1_round(&group, &mut opts, &mut rank_params, &grads, &mut scratches);
            }));
            // the acceptance assertion: steady-state sync allocated nothing
            for (r, (s, fp)) in scratches.iter().zip(&fingerprints).enumerate() {
                assert_eq!(
                    &scratch_fingerprint(s),
                    fp,
                    "rank {r} of {n}: zero1-live scratch reallocated in steady state"
                );
            }
        }
    }

    println!("\n=== slab pool (fresh-alloc vs recycle, counter semantics) ===");
    {
        use ppmoe::trainer::pool::LocalSlabPool;
        let len = 65_536; // one 256 KiB activation slab
        // fresh-alloc reference: a cold pool, every take is a miss
        results.push(bench("slab_pool/fresh 256KiB", || {
            let mut pool = LocalSlabPool::new();
            let v = pool.take(len);
            assert_eq!(
                (pool.hits, pool.misses, pool.prefilled),
                (0, 1, 0),
                "a cold take is a miss — never a hit"
            );
            v.capacity()
        }));
        // recycling path: one prefilled slab loops take -> put forever
        let mut pool = LocalSlabPool::new();
        pool.prefill(1, len);
        results.push(bench("slab_pool/recycled 256KiB", || {
            let v = pool.take(len);
            pool.put(v);
        }));
        // the accounting contract the trainer timers rely on: prefills are
        // neither hits nor misses, recycled takes are hits, and total
        // allocations == misses + prefilled (here: 0 + 1)
        assert_eq!(pool.prefilled, 1, "one slab seeded up front");
        assert_eq!(pool.misses, 0, "steady-state recycling never allocates");
        assert!(pool.hits > 0, "recycled takes count as hits");
    }

    println!("\n=== manifest JSON parse ===");
    let manifest_path = std::path::Path::new("artifacts/manifest.json");
    if manifest_path.exists() {
        let text = std::fs::read_to_string(manifest_path).unwrap();
        println!("manifest size: {} bytes", text.len());
        results.push(bench("manifest parse", || {
            ppmoe::util::json::parse(&text).unwrap()
        }));
    } else {
        println!("(artifacts/manifest.json missing — run `make artifacts`)");
    }

    write_json("BENCH_hotpath.json", &results);
    write_json("BENCH_comm.json", &comm_results);
}

/// One dp sync round over `nodes × g` ranks through the selected topology
/// path: `None` = flat single-level ring, `Some(pipelined)` = two-level
/// hierarchical group in the given forwarding mode. Every rank deposits a
/// rank-varying payload (so summation order is observable), reduce-scatters,
/// all-gathers, and rank 0's full gathered vector is returned for the
/// bitwise spot check.
fn dp_sync_hier_step(nodes: usize, g: usize, elems: usize, mode: Option<bool>) -> Vec<f32> {
    let n = nodes * g;
    let group = match mode {
        None => DpSyncGroup::Flat(AllReduceGroup::with_algo(n, Algo::Chunked)),
        Some(pipelined) => DpSyncGroup::Hier(HierarchicalGroup::with_mode(nodes, g, pipelined)),
    };
    let handles: Vec<_> = (0..n)
        .map(|rank| {
            let group = group.clone();
            std::thread::spawn(move || {
                let contrib: Vec<f32> =
                    (0..elems).map(|i| ((rank * 97 + i) % 1013) as f32 * 1e-3).collect();
                let mut seg = Vec::new();
                group.reduce_scatter_into(rank, &contrib, &mut seg);
                let full = group.all_gather_as(rank, &seg);
                (rank == 0).then(|| full.as_ref().clone())
            })
        })
        .collect();
    handles.into_iter().filter_map(|h| h.join().unwrap()).next().unwrap()
}

/// One wrap-edge hop chain: a producer thread reads a device buffer back
/// into a slab (d2h), sends it over an mpsc channel, and a consumer thread
/// (its own PJRT client — buffers are thread-affine) uploads it (h2d) and
/// returns the slab, which doubles as the ack. `window` bounds the
/// in-flight payloads: 1 serializes every hop on the consumer's ack,
/// 2 double-buffers — the producer's next d2h overlaps the consumer's
/// current h2d, which is exactly the trainer's staged wrap-edge pipeline.
fn wrap_edge_hops(elems: usize, hops: usize, window: usize) -> usize {
    use std::sync::mpsc::channel;
    let (tx, rx) = channel::<Vec<f32>>();
    let (ack_tx, ack_rx) = channel::<Vec<f32>>();
    let consumer = std::thread::spawn(move || {
        let client = xla::PjRtClient::cpu().expect("stub cpu client");
        let mut n = 0usize;
        for v in rx {
            let buf = client
                .buffer_from_host_buffer(&v, &[v.len()], None)
                .expect("h2d upload");
            n += buf.element_count();
            ack_tx.send(v).ok(); // slab return = ack
        }
        n
    });
    let producer = std::thread::spawn(move || {
        let client = xla::PjRtClient::cpu().expect("stub cpu client");
        let src = client
            .buffer_from_host_buffer(&vec![1.0f32; elems], &[elems], None)
            .expect("source buffer");
        let mut slabs: Vec<Vec<f32>> =
            (0..window).map(|_| Vec::with_capacity(elems)).collect();
        let mut in_flight = 0usize;
        for _ in 0..hops {
            if in_flight == window {
                slabs.push(ack_rx.recv().expect("ack"));
                in_flight -= 1;
            }
            let mut slab = slabs.pop().expect("slab window");
            src.copy_into(&mut slab).expect("d2h readback");
            tx.send(slab).ok();
            in_flight += 1;
        }
        drop(tx);
        while ack_rx.recv().is_ok() {}
    });
    producer.join().unwrap();
    consumer.join().unwrap()
}

/// A unit of "backward compute" standing in for one chunk's remaining
/// backward ops: a few fused passes over the bucket-sized buffer.
fn backward_spin(v: &mut [f32]) {
    for _ in 0..4 {
        for x in v.iter_mut() {
            *x = *x * 0.999 + 0.001;
        }
    }
}

/// One dp gradient-sync step over `buckets` per-(stage, chunk) groups:
/// every rank thread produces its buckets in sequence (compute spin), then
/// reduce-scatters + all-gathers each one. `overlap = false` syncs after
/// all compute (the trainer's `--no-dp-overlap`); `overlap = true` hands
/// each bucket to a per-bucket sync worker the moment it is produced, so
/// the collective overlaps the remaining compute (the live bucket hook).
fn dp_sync_step(dp: usize, buckets: usize, elems: usize, overlap: bool) -> f32 {
    use std::sync::mpsc::channel;
    let groups: Vec<Arc<AllReduceGroup>> =
        (0..buckets).map(|_| AllReduceGroup::with_algo(dp, Algo::Chunked)).collect();
    let handles: Vec<_> = (0..dp)
        .map(|rank| {
            let groups = groups.clone();
            std::thread::spawn(move || {
                let mut work: Vec<Vec<f32>> =
                    (0..buckets).map(|b| vec![(rank + b) as f32 * 1e-3; elems]).collect();
                if overlap {
                    // per-bucket sync workers, exactly the trainer's shape
                    let mut txs = Vec::new();
                    let mut rxs = Vec::new();
                    let mut workers = Vec::new();
                    for g in &groups {
                        let (btx, brx) = channel::<Vec<f32>>();
                        let (dtx, drx) = channel::<Vec<f32>>();
                        let g = g.clone();
                        workers.push(std::thread::spawn(move || {
                            for flat in brx {
                                let mut seg = Vec::new();
                                g.reduce_scatter_into(rank, &flat, &mut seg);
                                dtx.send(seg).ok();
                            }
                        }));
                        txs.push(btx);
                        rxs.push(drx);
                    }
                    for (b, w) in work.iter_mut().enumerate() {
                        backward_spin(w);
                        txs[b].send(std::mem::take(w)).ok();
                    }
                    let mut acc = 0.0f32;
                    for (b, rx) in rxs.iter().enumerate() {
                        let seg = rx.recv().expect("sync worker died");
                        acc += groups[b].all_gather_as(rank, &seg)[0];
                    }
                    drop(txs);
                    for w in workers {
                        w.join().unwrap();
                    }
                    acc
                } else {
                    for w in work.iter_mut() {
                        backward_spin(w);
                    }
                    let mut acc = 0.0f32;
                    let mut seg = Vec::new();
                    for (b, w) in work.iter().enumerate() {
                        groups[b].reduce_scatter_into(rank, w, &mut seg);
                        acc += groups[b].all_gather_as(rank, &seg)[0];
                    }
                    acc
                }
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).sum()
}

/// One live ZeRO-1 optimizer round: every rank runs
/// [`sharded_group_step_with`] over the shared group with its reused
/// scratch (n = 1 inline, n > 1 as a thread fan-out like the trainer).
fn run_zero1_round(
    group: &Arc<AllReduceGroup>,
    opts: &mut [ShardedAdam],
    rank_params: &mut [Vec<Tensor>],
    grads: &[Tensor],
    scratches: &mut [GroupStepScratch],
) {
    if opts.len() == 1 {
        sharded_group_step_with(
            &mut opts[0], group, &mut rank_params[0], grads, 0.25, &mut scratches[0],
        )
        .unwrap();
        return;
    }
    std::thread::scope(|s| {
        for ((opt, params), scratch) in
            opts.iter_mut().zip(rank_params.iter_mut()).zip(scratches.iter_mut())
        {
            let group = group.clone();
            let _ = s.spawn(move || {
                sharded_group_step_with(opt, &group, params, grads, 0.25, scratch).unwrap()
            });
        }
    });
}

/// Pointer + capacity fingerprint of a scratch's buffers: equality across
/// rounds proves the round performed zero heap allocations in these paths.
fn scratch_fingerprint(s: &GroupStepScratch) -> (usize, usize, usize, usize, usize, usize) {
    (
        s.flat.as_ptr() as usize,
        s.seg.as_ptr() as usize,
        s.shard.as_ptr() as usize,
        s.flat.capacity(),
        s.seg.capacity(),
        s.shard.capacity(),
    )
}

/// Emit a bench JSON (`BENCH_hotpath.json` / `BENCH_comm.json`): component
/// name -> ns/op stats.
fn write_json(path: &str, results: &[BenchResult]) {
    let mut components = BTreeMap::new();
    for r in results {
        let mut stats = BTreeMap::new();
        stats.insert("median_ns".to_string(), Json::Num(r.median_ns));
        stats.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
        stats.insert("p10_ns".to_string(), Json::Num(r.p10_ns));
        stats.insert("p90_ns".to_string(), Json::Num(r.p90_ns));
        stats.insert("iters".to_string(), Json::Num(r.iters as f64));
        components.insert(r.name.clone(), Json::Obj(stats));
    }
    let doc = Json::Obj(BTreeMap::from([(
        "components".to_string(),
        Json::Obj(components),
    )]));
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("\nwrote {path} ({} components)", results.len()),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
