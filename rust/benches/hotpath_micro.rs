//! Bench: L3 coordinator hot paths (the perf-pass targets of DESIGN §7).
//!
//! * router dispatch (route_top1) across token/expert scales
//! * in-process all-reduce across rank counts
//! * 1F1B schedule simulation
//! * fused Adam update
//! * manifest JSON parse
//!
//! Before/after numbers for each optimization iteration are recorded in
//! EXPERIMENTS.md §Perf.

use std::sync::Arc;

use ppmoe::comm::AllReduceGroup;
use ppmoe::moe::{route_top1, synth_logits};
use ppmoe::pipeline::{analytic_bubble, simulate, Schedule, StageTiming};
use ppmoe::runtime::Tensor;
use ppmoe::trainer::adam::Adam;
use ppmoe::util::bench::bench;
use ppmoe::util::prng::Rng;

fn main() {
    println!("=== router (route_top1) ===");
    let mut rng = Rng::new(1);
    for (tokens, experts) in [(2048, 8), (16384, 64), (65536, 64)] {
        let logits = synth_logits(&mut rng, tokens, experts, 0.5);
        bench(&format!("route_top1 t={tokens} E={experts}"), || {
            route_top1(&logits, experts, tokens).tokens()
        });
    }

    println!("\n=== in-process all-reduce ===");
    for ranks in [2usize, 4, 8] {
        let elems = 262_144; // 1 MiB of f32 per rank
        bench(&format!("all_reduce ranks={ranks} 1MiB"), || {
            let g = AllReduceGroup::new(ranks);
            let handles: Vec<_> = (0..ranks)
                .map(|r| {
                    let g: Arc<AllReduceGroup> = g.clone();
                    std::thread::spawn(move || {
                        let v = vec![r as f32; elems];
                        g.all_reduce(&v)[0]
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum::<f32>()
        });
    }

    println!("\n=== 1F1B schedule simulation ===");
    for (stages, micros) in [(4, 16), (16, 64), (64, 256)] {
        let timing = vec![StageTiming { fwd: 1.0, bwd: 2.0, p2p: 0.1 }; stages];
        bench(&format!("simulate p={stages} m={micros}"), || {
            let s = simulate(Schedule::OneFOneB, &timing, micros);
            assert!((s.bubble_fraction - analytic_bubble(stages, micros)).abs() < 0.5);
            s.makespan
        });
    }

    println!("\n=== fused Adam update ===");
    for numel in [65_536usize, 1_048_576] {
        let mut params = vec![Tensor::f32(vec![0.1; numel], vec![numel])];
        let grads = vec![Tensor::f32(vec![0.01; numel], vec![numel])];
        let mut opt = Adam::new(1e-3, &params);
        bench(&format!("adam update {numel} params"), || {
            opt.update(&mut params, &grads).unwrap();
        });
    }

    println!("\n=== manifest JSON parse ===");
    let manifest_path = std::path::Path::new("artifacts/manifest.json");
    if manifest_path.exists() {
        let text = std::fs::read_to_string(manifest_path).unwrap();
        println!("manifest size: {} bytes", text.len());
        bench("manifest parse", || {
            ppmoe::util::json::parse(&text).unwrap()
        });
    } else {
        println!("(artifacts/manifest.json missing — run `make artifacts`)");
    }
}
