//! Bench: §3.3.2's enabling observation — "the computational speed of
//! serially processing a few small tensors is nearly the same as processing
//! a big tensor".
//!
//! Real PJRT execution: `ffn_grouped` runs E expert FFNs over t/E tokens
//! each (the Pallas grouped kernel's grid loop — PPMoE's per-device expert
//! serialization); `ffn_mono` runs one dense FFN over all t tokens. Equal
//! FLOPs; the ratio of their times is the serialization overhead. The paper
//! found "little extra latency"; we report the measured ratio.
//!
//! Requires `make artifacts` (uses the default artifacts/ directory).

use ppmoe::runtime::{Runtime, Tensor};
use ppmoe::util::bench::{bench_n, fmt_ns};
use ppmoe::util::prng::Rng;

fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

fn main() -> anyhow::Result<()> {
    // cargo bench passes a --bench flag; take the first non-flag arg
    let dir = std::path::PathBuf::from(
        std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'))
            .unwrap_or_else(|| "artifacts".into()),
    );
    let mut rt = Runtime::open(&dir)?;
    let m = rt.manifest.model.clone();
    let (t, h) = (m.micro_batch * m.seq, m.hidden);
    let e = m.experts;
    let c = (t / e).max(1);
    // ffn dim from the artifact spec
    let mono = rt.load("ffn_mono")?;
    let f = mono.spec.inputs[1].shape[1];
    println!(
        "serialization experiment: {t} tokens, h={h}, f={f}; mono (1×{t}) vs \
         grouped ({e}×{c})"
    );

    let mut rng = Rng::new(0);
    let mono_in = vec![
        Tensor::f32(randn(&mut rng, t * h, 0.5), vec![t, h]),
        Tensor::f32(randn(&mut rng, h * f, 0.05), vec![h, f]),
        Tensor::f32(randn(&mut rng, f, 0.02), vec![f]),
        Tensor::f32(randn(&mut rng, f * h, 0.05), vec![f, h]),
        Tensor::f32(randn(&mut rng, h, 0.02), vec![h]),
    ];
    let grouped = rt.load("ffn_grouped")?;
    let grouped_in = vec![
        Tensor::f32(randn(&mut rng, e * c * h, 0.5), vec![e, c, h]),
        Tensor::f32(randn(&mut rng, e * h * f, 0.05), vec![e, h, f]),
        Tensor::f32(randn(&mut rng, e * f, 0.02), vec![e, f]),
        Tensor::f32(randn(&mut rng, e * f * h, 0.05), vec![e, f, h]),
        Tensor::f32(randn(&mut rng, e * h, 0.02), vec![e, h]),
    ];

    let iters = 30;
    let r_mono = bench_n("ffn_mono (one big GEMM)", iters, || {
        mono.run(&mono_in).unwrap().len()
    });
    let r_grp = bench_n("ffn_grouped (E serialized experts)", iters, || {
        grouped.run(&grouped_in).unwrap().len()
    });

    let ratio = r_grp.median_ns / r_mono.median_ns;
    println!(
        "\nserialization overhead: grouped/mono = {ratio:.2}x \
         (mono {} vs grouped {})",
        fmt_ns(r_mono.median_ns),
        fmt_ns(r_grp.median_ns)
    );
    println!(
        "paper §3.3.2 (V100): 'nearly the same'. On CPU-PJRT the Pallas\n\
         interpret-mode grid lowers to a sequential while-loop with\n\
         per-step dynamic-slice overhead, so the measured ratio approaches\n\
         O(E)={e} here — an interpret-mode artifact, not a property of the\n\
         kernel: on TPU the (E, C/blk) grid is weight-stationary and each\n\
         step still saturates the MXU (EXPERIMENTS.md §Serialization and\n\
         §Perf knobs).\n\
         The honest CPU-side conclusion matches footnote 6's caveat: the\n\
         claim rests on well-optimized device kernels."
    );
    anyhow::ensure!(
        ratio < 2.0 * e as f64,
        "grouped kernel exceeds even linear serialization cost"
    );
    Ok(())
}
