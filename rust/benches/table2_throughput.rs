//! Bench: regenerate Table 2 (all 13 throughput rows) and time the sweep.
//!
//! Paper reference: PPMoE reaches 81.4% (small) / 90.7% (large) of the
//! slowest dense baseline; best DPMoE reaches 66.2% / 26.1%; PPMoE beats
//! DPMoE by 1.25x (small) and 1.77x (large).

use ppmoe::coordinator::tables;
use ppmoe::util::bench::bench;

fn main() {
    println!("=== Table 2: training throughput ===");
    print!("{}", tables::table2_markdown().unwrap());

    let rows = tables::table2_rows().unwrap();
    let best = |range: std::ops::Range<usize>| -> f64 {
        rows[range]
            .iter()
            .map(|r| r.tokens_per_sec_per_gpu)
            .fold(0.0, f64::max)
    };
    println!("\nshape checks:");
    println!(
        "  small: PPMoE/bestDPMoE = {:.2}x (paper 1.25x)",
        rows[5].tokens_per_sec_per_gpu / best(3..5)
    );
    println!(
        "  large: PPMoE/bestDPMoE = {:.2}x (paper 1.77x)",
        rows[12].tokens_per_sec_per_gpu / best(9..12)
    );

    println!("\n=== Table 2 variant: interleaved virtual-stage 1F1B ===");
    print!("{}", tables::table2_interleaved_markdown().unwrap());
    println!(
        "(bubble shrinks as (p-1)/(m+p-1) -> (p-1)/(v*m+p-1); each microbatch\n\
         pays the stage-boundary p2p cost v times — docs/schedules.md)"
    );

    println!("\n=== simulator cost ===");
    bench("table2_full_sweep", || tables::table2_rows().unwrap().len());
    bench("table2_interleaved_sweep", || {
        tables::table2_interleaved_rows().unwrap().len()
    });
}
