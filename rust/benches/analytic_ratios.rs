//! Bench: the paper's analytic communication/compute ratios (Eq. 2, 3, 5).
//!
//! Regenerates the arithmetic of §3.2 with the paper's own constants:
//! * Eq. 2/3 — a2a/FFN latency ratio: > (E−1)·E/16 for inter-node IB.
//!   At E = 64 the bound is 252; at E = 256 it is 4080 — "these two
//!   all-to-all operations would be a critical bottleneck".
//! * Eq. 5 — TP all-reduce/compute ratio = (T−1)·T·F/(4·B·h) ≈ 6 at
//!   T = 8, h = 1000 over NVLink — "dramatically smaller".
//!
//! Also sweeps the α-β simulator's all-to-all vs all-reduce costs to show
//! where the crossover falls under the linear (measured-consistent) model.

use ppmoe::comm::cost::{paper, CostModel};
use ppmoe::config::v100_cluster;
use ppmoe::util::bench::bench;

const F: f64 = 125e12; // V100 fp16 peak
const B_IB: f64 = 12.5e9; // InfiniBand
const B_NVL: f64 = 300e9; // NVLink

fn main() {
    println!("=== Eq. 2/3: t_a2a / t_FFN (DPMoE, inter-node IB) ===");
    println!("{:>6} {:>12} {:>14} {:>14}", "E", "bound(E)", "h=1024", "h=4096");
    for e in [8.0, 16.0, 32.0, 64.0, 128.0, 256.0] {
        println!(
            "{:>6} {:>12.1} {:>14.1} {:>14.1}",
            e,
            paper::a2a_over_ffn_bound(e),
            paper::a2a_over_ffn(e, F, B_IB, 1024.0),
            paper::a2a_over_ffn(e, F, B_IB, 4096.0)
        );
    }
    // paper's claim: at E = 64, ratio >> 1 (a2a dominates)
    assert!(paper::a2a_over_ffn_bound(64.0) > 250.0);

    println!("\n=== Eq. 5: t_allreduce / t_cal (tensor parallel, NVLink) ===");
    println!("{:>6} {:>12} {:>12}", "T", "h=1000", "h=4096");
    for t in [2.0, 4.0, 8.0] {
        println!(
            "{:>6} {:>12.3} {:>12.3}",
            t,
            paper::allreduce_over_cal(t, F, B_NVL, 1000.0),
            paper::allreduce_over_cal(t, F, B_NVL, 4096.0)
        );
    }
    let r = paper::allreduce_over_cal(8.0, F, B_NVL, 1000.0);
    println!("paper check: T=8, h=1000 -> {r:.3} (paper: 35/6 ≈ 5.833)");
    assert!((r - 35.0 / 6.0).abs() < 1e-9);

    println!("\n=== α-β simulator: PPMoE all-reduce vs DPMoE a2a, per MoE layer ===");
    let cm = CostModel::new(v100_cluster(256));
    let bytes = (8 * 2048 * 4096 * 2) as f64; // b=8, s=2048, h=4096, fp16
    println!("{:>6} {:>16} {:>16} {:>10}", "ranks", "a2a (ms)", "allreduce (ms)", "a2a/ar");
    for n in [2usize, 4, 8, 16, 32, 64] {
        let a2a = cm.all_to_all(n, bytes).seconds * 1e3;
        let ar = cm.all_reduce(8.min(n), bytes).seconds * 1e3; // PPMoE: inner-node
        println!("{n:>6} {a2a:>16.2} {ar:>16.2} {:>10.1}", a2a / ar);
    }

    println!("\n=== micro timings ===");
    bench("eq2_eval", || paper::a2a_over_ffn(64.0, F, B_IB, 4096.0));
    bench("alpha_beta_a2a", || cm.all_to_all(64, bytes).seconds);
    bench("alpha_beta_allreduce", || cm.all_reduce(8, bytes).seconds);
}
