//! Bench: regenerate Table 3 (PPMoE forward breakdown) and time it.
//!
//! Paper reference (6.7B PPMoE, 32 V100): MoE fwd 38.2%, gating 7.8%,
//! expert calc 7.0%, MoE AR 20.7%, FFN AR 18.8% — and crucially
//! MoE AR ≈ FFN AR (within 1.9% of total), the §3.3.4 no-extra-comm claim.

use ppmoe::coordinator::tables;
use ppmoe::sim::Component;
use ppmoe::util::bench::bench;

fn main() {
    let bd = tables::table3_breakdown().unwrap();
    println!("=== Table 3: PPMoE forward breakdown ===");
    print!("{}", tables::table3_markdown().unwrap());

    let total = bd.total();
    let moe_ar = bd.get(Component::MoeAllReduce);
    let ffn_ar = bd.get(Component::FfnAllReduce);
    println!(
        "\nshape check: MoE {:.1}% (paper 38.2%), MoE AR {:.1}% (paper 20.7%)",
        bd.moe_total() / total * 100.0,
        moe_ar / total * 100.0
    );
    println!(
        "§3.3.4: MoE AR vs FFN AR differ by {:.2}% of total (paper: 1.9%)",
        (moe_ar - ffn_ar).abs() / total * 100.0
    );

    println!("\n=== simulator cost ===");
    bench("table3_breakdown_sim", || {
        tables::table3_breakdown().unwrap().total()
    });
}
