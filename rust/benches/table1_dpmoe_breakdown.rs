//! Bench: regenerate Table 1 (DPMoE forward breakdown) and time the
//! simulator. Prints the paper-layout table followed by timing stats.
//!
//! Paper reference (143B DPMoE, 256 V100): total 7617 ms, MoE fwd 82.6%,
//! a2a 65.5%, gating 2.1%, others 17.3%.

use ppmoe::coordinator::tables;
use ppmoe::sim::Component;
use ppmoe::util::bench::bench;

fn main() {
    let bd = tables::table1_breakdown().unwrap();
    println!("=== Table 1: DPMoE forward breakdown ===");
    print!("{}", tables::table1_markdown().unwrap());
    let total = bd.total();
    let a2a = bd.get(Component::FirstA2A) + bd.get(Component::SecondA2A);
    println!(
        "\nshape check: a2a {:.1}% (paper 65.5%), MoE {:.1}% (paper 82.6%), \
         gating {:.1}% (paper 2.1%)",
        a2a / total * 100.0,
        bd.moe_total() / total * 100.0,
        bd.get(Component::Gating) / total * 100.0
    );

    println!("\n=== simulator cost ===");
    bench("table1_breakdown_sim", || {
        tables::table1_breakdown().unwrap().total()
    });
}
