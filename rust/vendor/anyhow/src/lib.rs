//! Vendored minimal `anyhow` (the build is fully offline — see the crate
//! root docs of `ppmoe`). Implements exactly the API surface the repo uses:
//!
//! * [`Error`] — a context chain over an optional source error
//! * [`Result<T>`] with the `Error` default
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`
//! * `anyhow!`, `bail!`, `ensure!` macros
//! * `{e}` prints the outermost message; `{e:#}` prints the full chain
//!   separated by `": "` (matching real anyhow's alternate formatting)
//!
//! Not implemented (unused here): downcasting, backtraces, `Chain`
//! iteration, `#[source]` attribute handling.

use std::fmt;

/// Error: a stack of human-readable context frames, outermost first.
pub struct Error {
    /// `frames[0]` is the most recently attached context (outermost).
    frames: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { frames: vec![m.to_string()] }
    }

    /// Attach an outer context frame.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.frames.insert(0, c.to_string());
        self
    }

    /// The outermost message (same as `{}` formatting).
    pub fn to_string_outer(&self) -> &str {
        &self.frames[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, "outer: cause: root"
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // mirrors anyhow's Debug: message plus a Caused by: list
        write!(f, "{}", self.frames[0])?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, frame) in self.frames[1..].iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts via `?`. `Error` itself does NOT implement
// `std::error::Error` (exactly like real anyhow), which is what keeps this
// blanket impl coherent alongside the reflexive `From<Error>`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// `anyhow::Result<T>` — the crate-wide fallible type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] from format args.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_fail().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        let full = format!("{e:#}");
        assert!(full.starts_with("outer: "), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(5).unwrap_err()), "five");
        assert_eq!(format!("{}", f(50).unwrap_err()), "too big: 50");
        let e: Error = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
    }
}
