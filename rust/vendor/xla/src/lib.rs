//! Vendored stub of the `xla-rs` PJRT bindings.
//!
//! The container this repo builds in has no network and no PJRT shared
//! library, so the real `xla` crate cannot be fetched or linked. This stub
//! keeps the exact API shape `ppmoe::runtime` compiles against, with honest
//! semantics for everything that does not require an XLA compiler:
//!
//! * **Literals and device buffers are real**: `Literal::vec1`, `reshape`,
//!   `to_vec`, `buffer_from_host_buffer`, `to_literal_sync` all move bytes
//!   exactly like the real bindings (host copies standing in for
//!   host<->device DMA). The staging / readback hot paths in
//!   `ppmoe::runtime` are therefore exercisable and benchmarkable.
//! * **Compilation and execution are unavailable**: `HloModuleProto`
//!   parsing stores the artifact text, `compile` succeeds (deferring, as
//!   PJRT itself may), and `execute`/`execute_b` return
//!   [`Error::BackendUnavailable`]. Every caller in `ppmoe` is gated
//!   behind artifact presence, so `cargo test -q` never reaches execution
//!   without a real toolchain.
//!
//! Mirroring real PJRT, none of the handle types are `Send`: each worker
//! thread must own its client (enforced with a `PhantomData<Rc<()>>`).
//!
//! Contract note for `execute`/`execute_b` result shape: artifacts are
//! lowered with `return_tuple=True`; following xla-rs, the result row
//! holds a single tuple-shaped value (`result[0][0]`) which
//! `to_literal_sync().to_tuple()` decomposes. `PjRtLoadedExecutable` here
//! also exposes the per-element untupled row (`untuple_result`) that
//! `ppmoe::runtime::Executable::run_device` relies on; a real-backend port
//! supplies that via PJRT's `untuple_result` execute option.

use std::fmt;
use std::marker::PhantomData;
use std::path::Path;
use std::rc::Rc;

/// Stub error type. Implements `std::error::Error`, so it converts into
/// `anyhow::Error` through `?` exactly like the real crate's error.
#[derive(Debug)]
pub enum Error {
    /// Execution (or another PJRT capability) needs the real backend.
    BackendUnavailable(&'static str),
    /// Shape/dtype misuse detected host-side.
    Usage(String),
    /// Underlying I/O failure (artifact file reads).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BackendUnavailable(what) => write!(
                f,
                "xla stub: {what} requires the real xla-rs/PJRT backend \
                 (this offline build vendors a data-movement-only stub)"
            ),
            Error::Usage(m) => write!(f, "xla stub: {m}"),
            Error::Io(e) => write!(f, "xla stub: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Whether this build can actually EXECUTE compiled artifacts. The
/// vendored stub moves bytes but has no compiler, so this is `false`; a
/// real xla-rs/PJRT port returns `true`. Integration tests that need live
/// execution gate on this (via `rust/tests/common`) so a toolchain-equipped
/// CI run with AOT artifacts still reports an honest executed-vs-skipped
/// split instead of failing on `Error::BackendUnavailable`.
pub fn backend_available() -> bool {
    false
}

/// Marker making a type `!Send + !Sync` (PJRT handles are thread-affine).
type NotSend = PhantomData<Rc<()>>;

/// Element types that can cross the boundary.
pub trait Element: Copy + Default + 'static {
    fn dtype_tag() -> &'static str;
    fn store(data: &[Self]) -> Storage;
    fn load(s: &Storage) -> Result<&[Self]>;
}

/// Typed host storage backing literals and device buffers.
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }
}

impl Element for f32 {
    fn dtype_tag() -> &'static str {
        "f32"
    }
    fn store(data: &[f32]) -> Storage {
        Storage::F32(data.to_vec())
    }
    fn load(s: &Storage) -> Result<&[f32]> {
        match s {
            Storage::F32(v) => Ok(v),
            _ => Err(Error::Usage("literal is not f32".into())),
        }
    }
}

impl Element for i32 {
    fn dtype_tag() -> &'static str {
        "i32"
    }
    fn store(data: &[i32]) -> Storage {
        Storage::I32(data.to_vec())
    }
    fn load(s: &Storage) -> Result<&[i32]> {
        match s {
            Storage::I32(v) => Ok(v),
            _ => Err(Error::Usage("literal is not i32".into())),
        }
    }
}

/// Host literal: typed data + dims, or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    kind: LiteralKind,
}

#[derive(Debug, Clone, PartialEq)]
enum LiteralKind {
    Dense { data: Storage, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a host slice (copies).
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        Literal {
            kind: LiteralKind::Dense {
                dims: vec![data.len() as i64],
                data: T::store(data),
            },
        }
    }

    /// Tuple literal (what `return_tuple=True` artifacts produce).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { kind: LiteralKind::Tuple(elems) }
    }

    /// Reinterpret with new dims; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match &self.kind {
            LiteralKind::Dense { data, .. } => {
                let n: i64 = dims.iter().product();
                if n as usize != data.len() {
                    return Err(Error::Usage(format!(
                        "reshape {:?} onto {} elements",
                        dims,
                        data.len()
                    )));
                }
                Ok(Literal {
                    kind: LiteralKind::Dense { data: data.clone(), dims: dims.to_vec() },
                })
            }
            LiteralKind::Tuple(_) => Err(Error::Usage("cannot reshape a tuple".into())),
        }
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.kind {
            LiteralKind::Tuple(elems) => Ok(elems.clone()),
            LiteralKind::Dense { .. } => {
                Err(Error::Usage("literal is not a tuple".into()))
            }
        }
    }

    /// Number of scalar elements.
    pub fn element_count(&self) -> usize {
        match &self.kind {
            LiteralKind::Dense { data, .. } => data.len(),
            LiteralKind::Tuple(elems) => elems.iter().map(Literal::element_count).sum(),
        }
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        match &self.kind {
            LiteralKind::Dense { data, .. } => Ok(T::load(data)?.to_vec()),
            LiteralKind::Tuple(_) => Err(Error::Usage("to_vec on a tuple".into())),
        }
    }

    /// Copy out into a caller-owned buffer (cleared first) — the
    /// allocation-free readback used by the device-resident hot path.
    pub fn to_vec_into<T: Element>(&self, out: &mut Vec<T>) -> Result<()> {
        match &self.kind {
            LiteralKind::Dense { data, .. } => {
                out.clear();
                out.extend_from_slice(T::load(data)?);
                Ok(())
            }
            LiteralKind::Tuple(_) => Err(Error::Usage("to_vec_into on a tuple".into())),
        }
    }

    /// First element as f32 without materializing the full vector
    /// (scalar loss/aux readback).
    pub fn first_f32(&self) -> Result<f32> {
        match &self.kind {
            LiteralKind::Dense { data: Storage::F32(v), .. } => v
                .first()
                .copied()
                .ok_or_else(|| Error::Usage("first_f32 on empty literal".into())),
            _ => Err(Error::Usage("first_f32 on non-f32 literal".into())),
        }
    }
}

/// Parsed HLO module. The stub stores the artifact text verbatim.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: Rc<String>,
}

impl HloModuleProto {
    /// Read an HLO-text artifact. I/O errors surface here, so a missing or
    /// unreadable artifact fails loudly even under the stub.
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)?;
        Ok(HloModuleProto { text: Rc::new(text) })
    }
}

/// Computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// PJRT client handle (thread-affine).
#[derive(Debug)]
pub struct PjRtClient {
    _not_send: NotSend,
}

impl PjRtClient {
    /// The CPU client always constructs; capability errors surface at
    /// execute time (mirroring PJRT's lazy behavior).
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _not_send: PhantomData })
    }

    /// "Compile" an artifact: defers to execute under the stub.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {
            _comp: comp.clone(),
            client: PjRtClient { _not_send: PhantomData },
        })
    }

    /// Upload host data to a device buffer (a real copy under the stub, a
    /// host->device DMA under real PJRT).
    pub fn buffer_from_host_buffer<T: Element>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error::Usage(format!(
                "buffer_from_host_buffer: dims {dims:?} vs {} elements",
                data.len()
            )));
        }
        Ok(PjRtBuffer {
            data: T::store(data),
            dims: dims.iter().map(|&d| d as i64).collect(),
            _not_send: PhantomData,
        })
    }
}

/// Device-resident buffer (thread-affine, like real PJRT buffers).
#[derive(Debug)]
pub struct PjRtBuffer {
    data: Storage,
    dims: Vec<i64>,
    _not_send: NotSend,
}

impl PjRtBuffer {
    /// Synchronous device->host readback.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal {
            kind: LiteralKind::Dense { data: self.data.clone(), dims: self.dims.clone() },
        })
    }

    /// Device->host readback into a caller-owned buffer (cleared first),
    /// skipping the intermediate literal: the zero-allocation path.
    pub fn copy_into<T: Element>(&self, out: &mut Vec<T>) -> Result<()> {
        out.clear();
        out.extend_from_slice(T::load(&self.data)?);
        Ok(())
    }

    /// First element as f32 (scalar readback without a full transfer).
    pub fn first_f32(&self) -> Result<f32> {
        match &self.data {
            Storage::F32(v) => v
                .first()
                .copied()
                .ok_or_else(|| Error::Usage("first_f32 on empty buffer".into())),
            _ => Err(Error::Usage("first_f32 on non-f32 buffer".into())),
        }
    }

    /// On-device dims.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element count.
    pub fn element_count(&self) -> usize {
        self.data.len()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _comp: XlaComputation,
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Execute with host literals. Requires the real backend.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("execute"))
    }

    /// Execute with pre-staged device buffers. Requires the real backend.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("execute_b"))
    }

    /// Execute with device buffers, returning one buffer **per tuple
    /// element** of the result (PJRT's `untuple_result=true`). This is the
    /// device-resident path: outputs stay on device, no readback.
    /// Requires the real backend.
    pub fn execute_untupled(&self, _args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        Err(Error::BackendUnavailable("execute_untupled"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_literals_decompose() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32, 3.0])]);
        assert_eq!(t.element_count(), 3);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<f32>().unwrap(), vec![2.0, 3.0]);
        assert!(Literal::vec1(&[1.0f32]).to_tuple().is_err());
    }

    #[test]
    fn buffer_staging_roundtrip() {
        let client = PjRtClient::cpu().unwrap();
        let buf = client
            .buffer_from_host_buffer(&[5.0f32, 6.0], &[2], None)
            .unwrap();
        assert_eq!(buf.dims(), &[2]);
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![5.0, 6.0]);
        // allocation-free readback reuses the caller's vec
        let mut out = Vec::with_capacity(2);
        buf.copy_into(&mut out).unwrap();
        assert_eq!(out, vec![5.0, 6.0]);
        assert_eq!(buf.first_f32().unwrap(), 5.0);
        // shape mismatch is a usage error
        assert!(client
            .buffer_from_host_buffer(&[1.0f32], &[2], None)
            .is_err());
    }

    #[test]
    fn execution_requires_real_backend() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: Rc::new("HloModule m".into()) };
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let lit = Literal::vec1(&[1.0f32]);
        assert!(matches!(
            exe.execute::<Literal>(&[lit]).unwrap_err(),
            Error::BackendUnavailable(_)
        ));
        let buf = client.buffer_from_host_buffer(&[1.0f32], &[1], None).unwrap();
        assert!(exe.execute_b(&[&buf]).is_err());
        assert!(exe.execute_untupled(&[&buf]).is_err());
    }

    #[test]
    fn missing_artifact_file_errors() {
        assert!(HloModuleProto::from_text_file(Path::new("/nope/x.hlo.txt")).is_err());
    }
}
