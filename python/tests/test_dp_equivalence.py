"""Data-parallel ZeRO-1 equivalence, as a host-side numpy property.

Mirrors the Rust trainer's dp gradient-sync semantics (rust/src/trainer):

* each of ``dp`` replicas accumulates its contiguous microbatch block's
  gradients left-to-right in float32;
* the reduce-scatter sums the replica contributions **in rank order**,
  segment ``r`` of the flat space landing on rank ``r`` (the ``segment``
  split shared with the Rust collectives);
* rank ``r`` runs Adam only on its owned moment shard and the updated
  parameter shards are concatenated (all-gather).

The property under test is the one the live trainer's bitwise acceptance
rests on: the sharded path is **bit-for-bit** identical to a single
process that sums the same block gradients in the same rank order and runs
monolithic Adam — sharding moves arithmetic, it never changes it. Run via
``make test-dp`` (wired into CI's python job).
"""

import numpy as np
import pytest


def segment(rank: int, total: int, n: int):
    """Near-equal contiguous split; first ``total % n`` segments get one
    extra element — the sharding contract of the Rust ``segment()``."""
    base, rem = divmod(total, n)
    lo = rank * base + min(rank, rem)
    return lo, lo + base + (1 if rank < rem else 0)


def adam_update(p, m, v, g, lr, step, gscale):
    """One fused float32 Adam step (β = 0.9/0.95, eps 1e-8), elementwise —
    the same per-element arithmetic as the Rust ``adam_elem``."""
    f32 = np.float32
    b1, b2, eps, one = f32(0.9), f32(0.95), f32(1e-8), f32(1.0)
    gi = (g * f32(gscale)).astype(np.float32)
    m[:] = b1 * m + (one - b1) * gi
    v[:] = b2 * v + (one - b2) * gi * gi
    bc1 = one - b1 ** f32(step)
    bc2 = one - b2 ** f32(step)
    lr_t = f32(lr) * np.sqrt(bc2) / bc1
    p[:] = p - lr_t * m / (np.sqrt(v) + eps)


def block_summed(grads_per_replica):
    """Rank-order sum of the replica block gradients, from zeros — the
    per-element summation order of the chunked reduce-scatter."""
    acc = np.zeros_like(grads_per_replica[0])
    for g in grads_per_replica:
        acc = acc + g
    return acc


def run_monolithic(p0, grad_steps, lr, gscales):
    p = p0.copy()
    m = np.zeros_like(p)
    v = np.zeros_like(p)
    for t, (per_replica, gscale) in enumerate(zip(grad_steps, gscales), start=1):
        adam_update(p, m, v, block_summed(per_replica), lr, t, gscale)
    return p


def run_zero1(p0, grad_steps, lr, gscales, dp):
    """dp ranks: reduce-scatter → shard Adam → all-gather, per step."""
    total = p0.size
    ranks = [
        {
            "p": p0.copy(),
            "m": np.zeros(segment(r, total, dp)[1] - segment(r, total, dp)[0],
                          dtype=np.float32),
            "v": np.zeros(segment(r, total, dp)[1] - segment(r, total, dp)[0],
                          dtype=np.float32),
        }
        for r in range(dp)
    ]
    for t, (per_replica, gscale) in enumerate(zip(grad_steps, gscales), start=1):
        shards = []
        for r, state in enumerate(ranks):
            lo, hi = segment(r, total, dp)
            # reduce-scatter: rank-order sum of this rank's segment only
            seg = block_summed([g[lo:hi] for g in per_replica])
            pseg = state["p"][lo:hi]
            adam_update(pseg, state["m"], state["v"], seg, lr, t, gscale)
            state["p"][lo:hi] = pseg
            shards.append(pseg.copy())
        gathered = np.concatenate(shards) if shards else np.zeros(0, np.float32)
        for state in ranks:
            state["p"] = gathered.copy()
    # every rank holds identical parameters after the final gather
    for state in ranks[1:]:
        assert np.array_equal(state["p"], ranks[0]["p"])
    return ranks[0]["p"]


@pytest.mark.parametrize("dp", [2, 4])
@pytest.mark.parametrize("numel", [1, 7, 64, 1000])
@pytest.mark.parametrize("seed", [0, 1])
def test_zero1_sharded_adam_bitwise_equals_monolithic(dp, numel, seed):
    rng = np.random.default_rng(seed)
    p0 = rng.standard_normal(numel).astype(np.float32)
    steps = 5
    grad_steps = [
        [rng.standard_normal(numel).astype(np.float32) for _ in range(dp)]
        for _ in range(steps)
    ]
    gscales = [0.25 + rng.random() for _ in range(steps)]
    mono = run_monolithic(p0, grad_steps, 1e-2, gscales)
    shard = run_zero1(p0, grad_steps, 1e-2, gscales, dp)
    assert np.array_equal(mono, shard), "sharded ZeRO-1 diverged from monolithic"


def test_block_summation_order_is_what_dp_matches():
    # why the reference is "dp = 1 with summed gradients" rather than the
    # flat microbatch loop: (g0+g1)+(g2+g3) need not equal ((g0+g1)+g2)+g3
    # in float32 — the dp-equivalence contract pins the block association.
    rng = np.random.default_rng(7)
    micros = [rng.standard_normal(4096).astype(np.float32) for _ in range(4)]
    flat = micros[0] + micros[1] + micros[2] + micros[3]
    blocked = block_summed([micros[0] + micros[1], micros[2] + micros[3]])
    # numerically indistinguishable (absolute tolerance: elements near 0
    # make relative comparison meaningless)...
    assert np.allclose(flat, blocked, rtol=1e-4, atol=1e-5)
    # ...but not guaranteed bitwise — and the reference mode exists because
    # at least sometimes they genuinely differ
    assert not np.array_equal(flat, blocked), (
        "expected at least one ULP of difference between associations; "
        "if this ever flakes the reference mode is stronger than needed"
    )


def test_segment_partitions_exactly():
    for n in range(1, 9):
        for total in [0, 1, 5, 8, 17, 100]:
            covered = 0
            for r in range(n):
                lo, hi = segment(r, total, n)
                assert lo == covered and hi >= lo
                covered = hi
            assert covered == total
