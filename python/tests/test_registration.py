"""Guard against the unregistered-test class (ISSUE 7 satellite).

PR 5 discovered `rust/tests/dp_equivalence.rs` had been silently absent
from `cargo test` since PR 4 because integration-test autodiscovery is
disabled once any explicit `[[test]]` entry exists in Cargo.toml. This
module makes that failure mode impossible to repeat, from the python job
that runs in every CI matrix cell (the rust side carries a mirror of the
Cargo.toml check as a lib unit test for toolchain-equipped environments):

* every `rust/tests/*.rs` integration test has a `[[test]]` entry, and
  every `[[test]]` entry points at a file that exists;
* every `python/tests/test_*.py` is importable (syntax-error- and
  missing-dependency-skips surface here, not as silent non-collection)
  and defines at least one test;
* every pytest file the Makefile invokes by name actually exists.
"""
import importlib.util
import pathlib
import re
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[2]


def _cargo_test_names():
    cargo = (REPO / "Cargo.toml").read_text()
    names = []
    current = None
    for line in cargo.splitlines():
        line = line.strip()
        if line.startswith("[["):
            current = line
        elif current == "[[test]]" and line.startswith("name"):
            names.append(re.search(r'"([^"]+)"', line).group(1))
    return names


def test_every_rust_integration_test_is_registered():
    """autotests = false territory: a rust/tests/*.rs file missing from
    Cargo.toml compiles nothing and runs nothing — exactly the dp_equivalence
    regression. Fail loudly with the stanza to paste."""
    files = {p.stem for p in (REPO / "rust" / "tests").glob("*.rs")}
    registered = set(_cargo_test_names())
    missing = sorted(files - registered)
    assert not missing, (
        f"rust/tests/{missing[0]}.rs is not registered in Cargo.toml — "
        "cargo will silently skip it. Add:\n"
        + "\n".join(
            f'[[test]]\nname = "{m}"\npath = "rust/tests/{m}.rs"' for m in missing
        )
    )


def test_every_registered_rust_test_file_exists():
    files = {p.stem for p in (REPO / "rust" / "tests").glob("*.rs")}
    stale = sorted(set(_cargo_test_names()) - files)
    assert not stale, f"Cargo.toml [[test]] entries without a file: {stale}"


def test_every_python_test_module_is_collectable():
    """Import every python/tests/test_*.py the way pytest would. A module
    that raises anything but a pytest skip is broken; one with zero test
    callables is dead weight that LOOKS covered."""
    test_dir = REPO / "python" / "tests"
    sys.path.insert(0, str(test_dir))  # same-dir helpers (topk_ref)
    try:
        for path in sorted(test_dir.glob("test_*.py")):
            spec = importlib.util.spec_from_file_location(
                f"_reg_{path.stem}", path)
            mod = importlib.util.module_from_spec(spec)
            try:
                spec.loader.exec_module(mod)
            except pytest.skip.Exception:
                continue  # importorskip: collected, then skipped — fine
            tests = [n for n in dir(mod) if n.startswith("test_")]
            assert tests, f"{path.name} defines no tests"
    finally:
        sys.path.remove(str(test_dir))


def test_makefile_pytest_targets_reference_real_files():
    mk = (REPO / "Makefile").read_text()
    for ref in re.findall(r"python/tests/\S+\.py", mk):
        assert (REPO / ref).exists(), f"Makefile references missing {ref}"
