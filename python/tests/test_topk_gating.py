"""Top-k gating kernel pins + misconfiguration loud-errors (hypothesis-free).

Three layers of proof that run in any environment with jax + numpy (no
hypothesis needed, so the offline container executes these too):

1. Bitwise regression pins: `make_dispatch_topk(k=1)` == `make_dispatch`
   and `make_dispatch_topk(k=2)` == `make_dispatch_top2` — the generalized
   schedule changes NOTHING for existing top-1/top-2 artifacts.
2. Contract consistency: the jnp kernel's dispatch/combine tensors are
   bitwise equal to the loop-written numpy twin in topk_ref.py, including
   the one-expert-hot and all-assignments-dropped capacity edges.
3. Loud errors: k > num_experts and capacity_factor < 1/experts fail at
   config validation (and therefore before `compile.aot` writes anything),
   with messages that say what to change.
"""
import dataclasses

import numpy as np
import pytest

import topk_ref

jnp = pytest.importorskip("jax.numpy")

from compile.kernels import gating
from compile.model import ModelConfig
from compile.aot import CONFIGS


def _probs(seed, tokens, experts, skew=0.0):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((tokens, experts)).astype(np.float32)
    logits[:, 0] += np.float32(skew)
    return topk_ref.softmax_np(logits)


# --- 1. bitwise regression pins -------------------------------------------


@pytest.mark.parametrize("capacity", [1, 7, 32])
def test_topk_k1_is_bitwise_make_dispatch(capacity):
    probs = _probs(0, 24, 4)
    top1 = jnp.argmax(jnp.asarray(probs), axis=-1).astype(jnp.int32)
    d1, c1, a1 = gating.make_dispatch(jnp.asarray(probs), top1, 4, capacity)
    dk, ck, ak = gating.make_dispatch_topk(jnp.asarray(probs), 4, capacity, 1)
    assert np.array_equal(np.asarray(d1), np.asarray(dk))
    assert np.array_equal(np.asarray(c1), np.asarray(ck))
    assert np.asarray(a1) == np.asarray(ak)


@pytest.mark.parametrize("capacity", [1, 7, 32])
def test_topk_k2_is_bitwise_make_dispatch_top2(capacity):
    probs = _probs(1, 24, 4)
    d2, c2, a2 = gating.make_dispatch_top2(jnp.asarray(probs), 4, capacity)
    dk, ck, ak = gating.make_dispatch_topk(jnp.asarray(probs), 4, capacity, 2)
    assert np.array_equal(np.asarray(d2), np.asarray(dk))
    assert np.array_equal(np.asarray(c2), np.asarray(ck))
    assert np.asarray(a2) == np.asarray(ak)


# --- 2. jnp kernel vs numpy contract twin ---------------------------------


@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("capacity", [1, 5, 48])
@pytest.mark.parametrize("skew", [0.0, 6.0])
def test_topk_kernel_matches_numpy_twin(k, capacity, skew):
    experts = 4
    probs = _probs(2, 32, experts, skew)
    idx = topk_ref.topk_select(probs, k)
    gates = topk_ref.topk_gates(probs, idx)
    dn, cn = topk_ref.make_dispatch_topk_np(idx, gates, experts, capacity)
    dj, cj, _ = gating.make_dispatch_topk(
        jnp.asarray(probs), experts, capacity, k)
    assert np.array_equal(dn, np.asarray(dj))
    assert np.array_equal(cn, np.asarray(cj))


def test_topk_each_token_gets_k_distinct_experts():
    """Uncapped: exactly k dispatch entries per token, all on distinct
    experts, at most one slot per (token, expert) — the invariant that
    keeps the per-rank index-slice decomposition exact at any k."""
    experts, k, tokens = 8, 4, 16
    probs = _probs(3, tokens, experts)
    d, c, _ = gating.make_dispatch_topk(jnp.asarray(probs), experts, tokens, k)
    d = np.asarray(d)
    per_tok_expert = d.sum(-1)  # (t, E) slots per (token, expert)
    assert per_tok_expert.max() <= 1.0
    assert np.array_equal(per_tok_expert.sum(-1), np.full(tokens, float(k)))
    # gates renormalize over the winners: combine sums to 1 per token
    np.testing.assert_allclose(np.asarray(c).sum((1, 2)),
                               np.ones(tokens), rtol=1e-6)


def test_topk_one_expert_hot_overflow():
    """Every token's first choice is expert 0 with capacity 2: exactly two
    level-0 survivors, and the level-1 choices land at slab positions that
    account for ALL level-0 claims (dropped included) — kernel and twin
    agree bitwise."""
    experts, tokens, capacity = 4, 12, 2
    probs = _probs(4, tokens, experts, skew=12.0)
    assert (probs.argmax(-1) == 0).all()
    idx = topk_ref.topk_select(probs, 2)
    gates = topk_ref.topk_gates(probs, idx)
    dn, cn = topk_ref.make_dispatch_topk_np(idx, gates, experts, capacity)
    dj, _cj, _ = gating.make_dispatch_topk(
        jnp.asarray(probs), experts, capacity, 2)
    assert np.array_equal(dn, np.asarray(dj))
    assert dn[:, 0].sum() == 2.0  # expert 0 keeps its 2 slots, drops the rest


def test_topk_all_assignments_dropped_is_zero_row():
    """Capacity 1 with identical preferences: token 0 claims both experts'
    single slots, every later token loses both choices and its combine
    row is exactly zero (a dropped token contributes nothing — no leak)."""
    experts, tokens = 2, 8
    logits = np.zeros((tokens, experts), np.float32)
    logits[:, 0] = 2.0
    logits[:, 1] = 1.0
    probs = topk_ref.softmax_np(logits)
    d, c, _ = gating.make_dispatch_topk(jnp.asarray(probs), experts, 1, 2)
    d, c = np.asarray(d), np.asarray(c)
    assert d[0].sum() == 2.0  # token 0 holds expert 0 AND expert 1 slot 0
    assert np.array_equal(d[1:], np.zeros_like(d[1:]))
    assert np.array_equal(c[1:], np.zeros_like(c[1:]))


# --- 3. loud errors -------------------------------------------------------


def test_gating_rejects_k_above_num_experts():
    probs = jnp.asarray(_probs(5, 8, 4))
    with pytest.raises(ValueError, match="top_k .* num_experts"):
        gating.make_dispatch_topk(probs, 4, 8, 5)
    with pytest.raises(ValueError, match="top_k"):
        gating.make_dispatch_topk(probs, 4, 8, 0)


def test_config_rejects_k_above_experts():
    cfg = dataclasses.replace(CONFIGS["tiny"], top_k=99)
    with pytest.raises(ValueError, match="top_k \\(99\\)"):
        cfg.validate()


def test_config_rejects_starving_capacity_factor():
    """cf < 1/experts means the total slot budget rounds toward zero —
    silently dropping nearly every token. Refused with advice."""
    tiny = CONFIGS["tiny"]
    cfg = dataclasses.replace(tiny, capacity_factor=0.5 / tiny.experts)
    with pytest.raises(ValueError, match="capacity_factor .* below"):
        cfg.validate()
    # cf = 0 stays the documented "uncapped" setting — NOT an error
    dataclasses.replace(tiny, capacity_factor=0.0).validate()


def test_capacity_scales_with_k():
    """capacity = cf·k·tokens/E (rounded up to 8s): doubling k doubles the
    slot budget so a balanced top-k load fits exactly like top-1 did."""
    tiny = CONFIGS["tiny"]
    base = tiny.capacity
    k2 = dataclasses.replace(tiny, top_k=2).capacity
    assert k2 == min(tiny.tokens, 2 * base) or k2 >= base
    # exact law away from the clamps
    cfg = dataclasses.replace(tiny, capacity_factor=1.0, top_k=2)
    raw = int(cfg.capacity_factor * cfg.top_k * cfg.tokens / cfg.experts)
    expect = min(cfg.tokens, max(8, -(-raw // 8) * 8))
    assert cfg.capacity == expect


def test_aot_export_rejects_bad_topk(tmp_path):
    """The export path refuses to write artifacts for an unroutable
    schedule: the error fires in validate(), before any file exists."""
    from compile import aot
    with pytest.raises(ValueError, match="top_k"):
        aot.export("tiny", str(tmp_path), tp=0, seed=0, include_full=False,
                   top_k=99)
    assert list(tmp_path.iterdir()) == []
