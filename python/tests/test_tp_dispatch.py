"""Index-slicing dispatch vs a dense all-to-all oracle (numpy property).

The paper's §3.3.3 claim, as a host-side property: PPMoE's per-rank
"tensor index slicing" of the dispatch/combine tensors — each rank keeping
only its E/T local experts' rows and contributing a partial output summed
by ONE inner-node all-reduce — computes exactly what DPMoE's two
all-to-alls compute (dispatch tokens to expert owners, gather results
back). With top-1 gating each token lands in exactly one expert's slice,
so the rank decomposition isn't just close: the partial sum touches one
nonzero term per token and the equality is EXACT in float32.

At k > 1 a token owns k slots spread over up to k ranks, so the equality
needs a declared reduction order: both sides fold per-expert contributions
under the fixed rank-order summation contract of topk_ref.fold_rank_order
(ascending experts within a rank, ascending ranks across), which is the
order the live trainer's rank-order all-reduce performs. Under that
contract the sweep below proves bitwise equality for k ∈ {1, 2, 4} ×
capacity factor × skewed routing distributions, including the
all-assignments-dropped and one-expert-hot edge cases.

Runs under hypothesis when available (CI's python job); the offline
container without hypothesis skips, mirroring the other kernel sweeps.
"""
import numpy as np
import pytest

import topk_ref

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st


def make_dispatch(top1, probs, experts, capacity):
    """Capacity-based one-hot dispatch/combine (the kernel contract):
    dispatch[t, e, c] = 1 iff token t is slot c of expert e."""
    t = top1.shape[0]
    dispatch = np.zeros((t, experts, capacity), np.float32)
    combine = np.zeros((t, experts, capacity), np.float32)
    fill = np.zeros(experts, np.int64)
    for tok in range(t):
        e = top1[tok]
        if fill[e] < capacity:
            dispatch[tok, e, fill[e]] = 1.0
            combine[tok, e, fill[e]] = probs[tok, e]
            fill[e] += 1
    return dispatch, combine


def expert_fn(xd, w):
    """Per-expert linear stand-in for the expert FFN: xd (E, C, h) -> same."""
    return topk_ref.expert_fn(xd, w)


def all_to_all_oracle(x, top1, probs, w, experts, capacity):
    """DPMoE semantics: globally dispatch every token to its expert's
    buffer (1st a2a), compute every expert, gather each token's result
    back (2nd a2a)."""
    dispatch, combine = make_dispatch(top1, probs, experts, capacity)
    xd = np.einsum("tec,th->ech", dispatch, x).astype(np.float32)
    yd = expert_fn(xd, w)
    return np.einsum("tec,eco->to", combine, yd).astype(np.float32)


def index_slice_ranks(x, top1, probs, w, experts, capacity, tp):
    """PPMoE semantics: every rank holds the full dispatch order (identical
    gating), index-slices its E/tp local experts, computes a partial, and
    the partials are summed in rank order (the inner-node all-reduce)."""
    dispatch, combine = make_dispatch(top1, probs, experts, capacity)
    n_loc = experts // tp
    total = None
    for r in range(tp):
        lo = r * n_loc
        d_loc = dispatch[:, lo:lo + n_loc, :]
        c_loc = combine[:, lo:lo + n_loc, :]
        xd = np.einsum("tec,th->ech", d_loc, x).astype(np.float32)
        yd = expert_fn(xd, w[lo:lo + n_loc])
        y_r = np.einsum("tec,eco->to", c_loc, yd).astype(np.float32)
        total = y_r if total is None else (total + y_r).astype(np.float32)
    return total


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tokens=st.integers(1, 48),
    hidden=st.sampled_from([4, 8, 16]),
    experts_per_rank=st.integers(1, 4),
    tp=st.sampled_from([1, 2, 4]),
    cap_frac=st.floats(0.25, 1.0),
)
def test_index_slice_equals_all_to_all(seed, tokens, hidden,
                                       experts_per_rank, tp, cap_frac):
    experts = experts_per_rank * tp
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((tokens, hidden)).astype(np.float32)
    w = (0.3 * rng.standard_normal((experts, hidden, hidden))).astype(
        np.float32)
    logits = rng.standard_normal((tokens, experts)).astype(np.float32)
    probs = topk_ref.softmax_np(logits)
    top1 = probs.argmax(-1)
    capacity = max(1, int(cap_frac * tokens))  # dropped tokens included

    oracle = all_to_all_oracle(x, top1, probs, w, experts, capacity)
    sliced = index_slice_ranks(x, top1, probs, w, experts, capacity, tp)
    # top-1: each token's combine row has ONE nonzero expert, so the rank
    # partial sum adds (tp - 1) exact zeros — bitwise equality, not approx
    assert np.array_equal(oracle, sliced), (
        f"max err {np.max(np.abs(oracle - sliced))}"
    )


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), tp=st.sampled_from([2, 4]))
def test_rank_partials_are_genuinely_partial(seed, tp):
    """Sanity on the decomposition: a single rank's partial differs from
    the combined result whenever several ranks' experts are hit (the
    all-reduce is load-bearing, not a formality)."""
    rng = np.random.default_rng(seed)
    tokens, hidden, experts = 32, 8, 2 * tp
    n_loc = experts // tp
    x = rng.standard_normal((tokens, hidden)).astype(np.float32)
    w = rng.standard_normal((experts, hidden, hidden)).astype(np.float32)
    top1 = rng.integers(0, experts, tokens)  # uniform: all ranks hit w.h.p.
    probs = np.full((tokens, experts), 1.0 / experts, np.float32)
    full = index_slice_ranks(x, top1, probs, w, experts, tokens, tp)
    # rank 0's lone partial: same FULL-expert dispatch order, sliced to its
    # local experts only (exactly what one rank computes before combining)
    dispatch, combine = make_dispatch(top1, probs, experts, tokens)
    xd = np.einsum("tec,th->ech", dispatch[:, :n_loc, :], x).astype(np.float32)
    yd = expert_fn(xd, w[:n_loc])
    lone = np.einsum("tec,eco->to", combine[:, :n_loc, :], yd).astype(np.float32)
    hits = len(np.unique(top1 // n_loc))
    if hits > 1:
        assert not np.allclose(full, lone)


# ---------------------------------------------------------------------------
# top-k: weighted combine, capacity drops, skewed distributions
# ---------------------------------------------------------------------------


def _skewed_probs(rng, tokens, experts, skew):
    """Softmax with expert 0 biased by `skew` logits: skew = 0 is the
    uniform-ish standard-normal case, skew ~ 6 concentrates >99% of top-1
    choices on one expert — the regime where capacity drops dominate."""
    logits = rng.standard_normal((tokens, experts)).astype(np.float32)
    logits[:, 0] += np.float32(skew)
    return topk_ref.softmax_np(logits)


@settings(max_examples=80, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tokens=st.integers(1, 48),
    hidden=st.sampled_from([4, 8]),
    out_dim=st.sampled_from([4, 8]),
    experts_per_rank=st.integers(1, 4),
    tp=st.sampled_from([1, 2, 4]),
    k=st.sampled_from([1, 2, 4]),
    cap_frac=st.floats(0.1, 1.5),
    skew=st.floats(0.0, 6.0),
)
def test_topk_index_slice_equals_all_to_all(seed, tokens, hidden, out_dim,
                                            experts_per_rank, tp, k,
                                            cap_frac, skew):
    """The tentpole property: at any k ≤ E, with any capacity (including
    one that drops most assignments) and any routing skew, the index-slice
    rank decomposition is BITWISE equal to the dense all-to-all oracle
    under the fixed rank-order summation contract."""
    experts = experts_per_rank * tp
    if k > experts:
        k = experts  # the kernel rejects k > E; clamp inside the sweep
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((tokens, hidden)).astype(np.float32)
    w = (0.3 * rng.standard_normal((experts, hidden, out_dim))).astype(
        np.float32)
    probs = _skewed_probs(rng, tokens, experts, skew)
    idx = topk_ref.topk_select(probs, k)
    gates = topk_ref.topk_gates(probs, idx)
    # k·tokens assignments compete for E·capacity slots: cap_frac < 1/k
    # guarantees drops even under perfectly uniform routing
    capacity = max(1, int(cap_frac * tokens))

    oracle = topk_ref.all_to_all_oracle_topk(
        x, idx, gates, w, experts, capacity, tp)
    sliced = topk_ref.index_slice_ranks_topk(
        x, idx, gates, w, experts, capacity, tp)
    assert np.array_equal(oracle, sliced), (
        f"k={k} tp={tp} cap={capacity} max err "
        f"{np.max(np.abs(oracle - sliced))}"
    )


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tp=st.sampled_from([1, 2, 4]),
    k=st.sampled_from([2, 4]),
)
def test_topk_one_expert_hot(seed, tp, k):
    """One-expert-hot edge: every token's top choice is the same expert
    (huge skew), so that expert's slab overflows immediately and the
    surviving signal flows through the level-1+ choices. Equality must
    hold when one rank does nearly all the work and the others almost
    none."""
    experts = 4 * max(tp, 1)
    tokens, hidden = 32, 8
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((tokens, hidden)).astype(np.float32)
    w = (0.3 * rng.standard_normal((experts, hidden, hidden))).astype(
        np.float32)
    probs = _skewed_probs(rng, tokens, experts, skew=12.0)
    assert (probs.argmax(-1) == 0).all()  # genuinely hot
    idx = topk_ref.topk_select(probs, k)
    gates = topk_ref.topk_gates(probs, idx)
    capacity = 2  # far below tokens: almost all level-0 choices drop

    oracle = topk_ref.all_to_all_oracle_topk(
        x, idx, gates, w, experts, capacity, tp)
    sliced = topk_ref.index_slice_ranks_topk(
        x, idx, gates, w, experts, capacity, tp)
    assert np.array_equal(oracle, sliced)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), tp=st.sampled_from([1, 2]))
def test_topk_all_assignments_dropped(seed, tp):
    """All-tokens-dropped edge: capacity 1 with every token preferring the
    same two experts — token 0 claims both slots, every other token loses
    BOTH its choices and must come back as an exact zero row on both
    sides (drops zero the combine entry; nothing leaks)."""
    experts = 2 * tp if tp > 1 else 2
    tokens, hidden = 16, 8
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((tokens, hidden)).astype(np.float32)
    w = (0.3 * rng.standard_normal((experts, hidden, hidden))).astype(
        np.float32)
    # deterministic preference order 0 then 1 for every token
    logits = np.zeros((tokens, experts), np.float32)
    logits[:, 0] = 2.0
    logits[:, 1] = 1.0
    probs = topk_ref.softmax_np(logits)
    idx = topk_ref.topk_select(probs, 2)
    gates = topk_ref.topk_gates(probs, idx)

    oracle = topk_ref.all_to_all_oracle_topk(x, idx, gates, w, experts, 1, tp)
    sliced = topk_ref.index_slice_ranks_topk(x, idx, gates, w, experts, 1, tp)
    assert np.array_equal(oracle, sliced)
    # token 0 survives; tokens 1.. are fully dropped -> exact zeros
    assert np.any(oracle[0] != 0.0)
    assert np.array_equal(oracle[1:], np.zeros_like(oracle[1:]))


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tokens=st.integers(1, 32),
    experts=st.sampled_from([2, 4, 8]),
    cap_frac=st.floats(0.25, 1.0),
)
def test_topk_k1_matches_top1_helper(seed, tokens, experts, cap_frac):
    """Regression pin inside the sweep: the k-generalized numpy contract at
    k = 1 builds bitwise the same dispatch/combine as the original top-1
    helper, so the old proof is a special case of the new one."""
    rng = np.random.default_rng(seed)
    probs = topk_ref.softmax_np(
        rng.standard_normal((tokens, experts)).astype(np.float32))
    top1 = probs.argmax(-1)
    capacity = max(1, int(cap_frac * tokens))
    d1, c1 = make_dispatch(top1, probs, experts, capacity)
    idx = topk_ref.topk_select(probs, 1)
    gates = topk_ref.topk_gates(probs, idx)
    dk, ck = topk_ref.make_dispatch_topk_np(idx, gates, experts, capacity)
    assert np.array_equal(d1, dk)
    assert np.array_equal(c1, ck)
