"""Index-slicing dispatch vs a dense all-to-all oracle (numpy property).

The paper's §3.3.3 claim, as a host-side property: PPMoE's per-rank
"tensor index slicing" of the dispatch/combine tensors — each rank keeping
only its E/T local experts' rows and contributing a partial output summed
by ONE inner-node all-reduce — computes exactly what DPMoE's two
all-to-alls compute (dispatch tokens to expert owners, gather results
back). With top-1 gating each token lands in exactly one expert's slice,
so the rank decomposition isn't just close: the partial sum touches one
nonzero term per token and the equality is EXACT in float32.

Runs under hypothesis when available (CI's python job); the offline
container without hypothesis skips, mirroring the other kernel sweeps.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st


def make_dispatch(top1, probs, experts, capacity):
    """Capacity-based one-hot dispatch/combine (the kernel contract):
    dispatch[t, e, c] = 1 iff token t is slot c of expert e."""
    t = top1.shape[0]
    dispatch = np.zeros((t, experts, capacity), np.float32)
    combine = np.zeros((t, experts, capacity), np.float32)
    fill = np.zeros(experts, np.int64)
    for tok in range(t):
        e = top1[tok]
        if fill[e] < capacity:
            dispatch[tok, e, fill[e]] = 1.0
            combine[tok, e, fill[e]] = probs[tok, e]
            fill[e] += 1
    return dispatch, combine


def expert_fn(xd, w):
    """Per-expert linear stand-in for the expert FFN: xd (E, C, h) -> same."""
    return np.einsum("ech,eho->eco", xd, w).astype(np.float32)


def all_to_all_oracle(x, top1, probs, w, experts, capacity):
    """DPMoE semantics: globally dispatch every token to its expert's
    buffer (1st a2a), compute every expert, gather each token's result
    back (2nd a2a)."""
    dispatch, combine = make_dispatch(top1, probs, experts, capacity)
    xd = np.einsum("tec,th->ech", dispatch, x).astype(np.float32)
    yd = expert_fn(xd, w)
    return np.einsum("tec,eco->to", combine, yd).astype(np.float32)


def index_slice_ranks(x, top1, probs, w, experts, capacity, tp):
    """PPMoE semantics: every rank holds the full dispatch order (identical
    gating), index-slices its E/tp local experts, computes a partial, and
    the partials are summed in rank order (the inner-node all-reduce)."""
    dispatch, combine = make_dispatch(top1, probs, experts, capacity)
    n_loc = experts // tp
    total = None
    for r in range(tp):
        lo = r * n_loc
        d_loc = dispatch[:, lo:lo + n_loc, :]
        c_loc = combine[:, lo:lo + n_loc, :]
        xd = np.einsum("tec,th->ech", d_loc, x).astype(np.float32)
        yd = expert_fn(xd, w[lo:lo + n_loc])
        y_r = np.einsum("tec,eco->to", c_loc, yd).astype(np.float32)
        total = y_r if total is None else (total + y_r).astype(np.float32)
    return total


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tokens=st.integers(1, 48),
    hidden=st.sampled_from([4, 8, 16]),
    experts_per_rank=st.integers(1, 4),
    tp=st.sampled_from([1, 2, 4]),
    cap_frac=st.floats(0.25, 1.0),
)
def test_index_slice_equals_all_to_all(seed, tokens, hidden,
                                       experts_per_rank, tp, cap_frac):
    experts = experts_per_rank * tp
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((tokens, hidden)).astype(np.float32)
    w = (0.3 * rng.standard_normal((experts, hidden, hidden))).astype(
        np.float32)
    logits = rng.standard_normal((tokens, experts)).astype(np.float32)
    probs = (np.exp(logits) /
             np.exp(logits).sum(-1, keepdims=True)).astype(np.float32)
    top1 = probs.argmax(-1)
    capacity = max(1, int(cap_frac * tokens))  # dropped tokens included

    oracle = all_to_all_oracle(x, top1, probs, w, experts, capacity)
    sliced = index_slice_ranks(x, top1, probs, w, experts, capacity, tp)
    # top-1: each token's combine row has ONE nonzero expert, so the rank
    # partial sum adds (tp - 1) exact zeros — bitwise equality, not approx
    assert np.array_equal(oracle, sliced), (
        f"max err {np.max(np.abs(oracle - sliced))}"
    )


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), tp=st.sampled_from([2, 4]))
def test_rank_partials_are_genuinely_partial(seed, tp):
    """Sanity on the decomposition: a single rank's partial differs from
    the combined result whenever several ranks' experts are hit (the
    all-reduce is load-bearing, not a formality)."""
    rng = np.random.default_rng(seed)
    tokens, hidden, experts = 32, 8, 2 * tp
    n_loc = experts // tp
    x = rng.standard_normal((tokens, hidden)).astype(np.float32)
    w = rng.standard_normal((experts, hidden, hidden)).astype(np.float32)
    top1 = rng.integers(0, experts, tokens)  # uniform: all ranks hit w.h.p.
    probs = np.full((tokens, experts), 1.0 / experts, np.float32)
    full = index_slice_ranks(x, top1, probs, w, experts, tokens, tp)
    # rank 0's lone partial: same FULL-expert dispatch order, sliced to its
    # local experts only (exactly what one rank computes before combining)
    dispatch, combine = make_dispatch(top1, probs, experts, tokens)
    xd = np.einsum("tec,th->ech", dispatch[:, :n_loc, :], x).astype(np.float32)
    yd = expert_fn(xd, w[:n_loc])
    lone = np.einsum("tec,eco->to", combine[:, :n_loc, :], yd).astype(np.float32)
    hits = len(np.unique(top1 // n_loc))
    if hits > 1:
        assert not np.allclose(full, lone)
