"""TP-pipeline segment calculus: the expert-sharded chunk decomposition the
live trainer's ``--tp n`` executes must reproduce the monolithic chunk.

This exercises the EXACT factory functions ``aot.py --tp-pipeline`` lowers
(stages.make_tp_glue_*/make_tp_moe_seg_*/make_tp_losstail), composed the way
the Rust trainer composes them:

* forward: glue segments replicated, per-rank MoE partials summed in rank
  order at each cut (the inner-node all-reduce), the residual add INSIDE
  the post-combine glue;
* backward: reverse walk; d(hgt) and d(wg) are rank-order sums of the rank
  partials, the aux cotangent goes to rank 0 only, glue gradients are
  taken from any single rank (replicated);
* expert gradients stay local; concatenating the rank slices reconstructs
  the monolithic expert gradient.

Against ``model.chunk_fwd`` / its jax.vjp, forward outputs and every
parameter gradient must agree to fp32 tolerance for every (stage, chunk)
of the tiny and tiny-deep(v=2) configs — dense-only chunks, mid-chunk MoE
chunks and the MoE-bearing loss chunk included.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, stages
from compile.aot import CONFIGS


def tol(a, b, what, rtol=3e-4, atol=3e-5):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, f"{what}: shape {a.shape} vs {b.shape}"
    assert np.allclose(a, b, rtol=rtol, atol=atol), (
        f"{what}: max abs err {np.max(np.abs(a - b))}"
    )


def seg_states(cfg, stage, chunk, tp):
    """(plan, per-rank per-seg param dicts + flattening metadata)."""
    plan = stages.tp_chunk_plan(cfg, stage, chunk)
    v_idx = chunk * cfg.stages + stage
    key = jax.random.PRNGKey(17 + v_idx)
    cp = model.init_chunk(key, cfg, stage, chunk)
    pdicts = [
        [
            stages.tp_segment_params(cp, seg, cfg, r, tp, k == 0, v_idx)
            for k, seg in enumerate(plan)
        ]
        for r in range(tp)
    ]
    return plan, cp, pdicts


def chunk_input(cfg, stage, chunk, seed=3):
    if stage == 0 and chunk == 0:
        return jax.random.randint(
            jax.random.PRNGKey(seed), (cfg.micro_batch, cfg.seq), 0, cfg.vocab
        )
    return 0.5 * jax.random.normal(
        jax.random.PRNGKey(seed), (cfg.micro_batch, cfg.seq, cfg.hidden)
    )


def run_segmented_fwd(cfg, stage, chunk, tp, plan, pdicts, x):
    """Trainer-faithful forward walk. Returns (out, aux, stash) where stash
    holds each segment's inputs for the backward."""
    cur = (x,)
    aux_total = jnp.float32(0.0)
    stash = []
    for k, seg in enumerate(plan):
        first = k == 0
        if seg["kind"] == "moe":
            hgt = cur[1]
            y = None
            for r in range(tp):
                fn, _, _ = stages.make_tp_moe_seg_fwd(cfg, r, tp, pdicts[r][k])
                leaves = stages.flatten_params(pdicts[r][k])[1]
                y_r, aux_r = fn(*leaves, hgt)
                y = y_r if y is None else y + y_r  # rank-order sum
                if r == 0:
                    aux_total = aux_total + aux_r
            stash.append((hgt,))
            cur = (cur[0], y)
        elif seg["kind"] == "glue":
            fn, _, _ = stages.make_tp_glue_fwd(cfg, stage, chunk, seg,
                                               pdicts[0][k], first)
            leaves = stages.flatten_params(pdicts[0][k])[1]
            stash.append(cur)
            cur = fn(*leaves, *cur)
        else:  # losstail executes at backward time (fused)
            stash.append(cur)
            cur = None
    return cur, aux_total, stash


def run_segmented_bwd(cfg, stage, chunk, tp, plan, pdicts, stash,
                      final_ct, targets=None, aux_in=None):
    """Trainer-faithful backward walk. ``final_ct`` is (dh, daux) for a
    pipeline chunk (daux = the aux cotangent constant) or None for the loss
    chunk (rooted in the losstail). Returns (loss_or_None, dx_or_None,
    grads) with grads[rank][seg] an unflattened param-grad dict."""
    aux_coef = jnp.float32(cfg.aux_coef)
    grads = [[None] * len(plan) for _ in range(tp)]
    loss = None
    # cotangents flowing upstream (reverse walk); a pipeline chunk's root is
    # the external (dh,) — the aux cotangent is applied at each moe segment,
    # not at the chunk boundary
    cts = (final_ct[0],) if final_ct is not None else None
    for k in range(len(plan) - 1, -1, -1):
        seg = plan[k]
        first = k == 0
        if seg["kind"] == "losstail":
            fn, _, names = stages.make_tp_losstail(cfg, stage, chunk, seg,
                                                   pdicts[0][k], first)
            leaves, treedef = stages.flatten_params(pdicts[0][k])[1:]
            out = fn(*leaves, *stash[k], targets, aux_in)
            loss = out[0]
            ndx = len(stash[k]) if not (first and stage == 0 and chunk == 0) else 0
            cts = out[1:1 + ndx]
            dp = stages.unflatten_params(treedef, list(out[1 + ndx:]))
            for r in range(tp):
                grads[r][k] = dp
        elif seg["kind"] == "glue":
            fn, _, _ = stages.make_tp_glue_bwd(cfg, stage, chunk, seg,
                                               pdicts[0][k], first)
            treedef = stages.flatten_params(pdicts[0][k])[2]
            leaves = stages.flatten_params(pdicts[0][k])[1]
            out = fn(*leaves, *stash[k], *cts)
            ndx = len(stash[k]) if not (first and stage == 0 and chunk == 0
                                        and not seg["post_moe"]) else 0
            new_cts = out[:ndx]
            dp = stages.unflatten_params(treedef, list(out[ndx:]))
            for r in range(tp):
                grads[r][k] = dp
            cts = new_cts
        else:  # moe: per-rank partials, dhgt/dwg rank-order summed
            dx2_ct, dy_ct = cts[0], cts[1]
            dhgt = None
            for r in range(tp):
                fn, _, _ = stages.make_tp_moe_seg_bwd(cfg, r, tp, pdicts[r][k])
                leaves, treedef = stages.flatten_params(pdicts[r][k])[1:]
                daux_r = aux_coef if r == 0 else jnp.float32(0.0)
                out = fn(*leaves, stash[k][0], dy_ct, daux_r)
                dhgt = out[0] if dhgt is None else dhgt + out[0]
                grads[r][k] = stages.unflatten_params(treedef, list(out[1:]))
            cts = (dx2_ct, dhgt)
    dx = cts[0] if cts else None
    return loss, dx, grads


def combine_param_grads(plan, pdicts, grads, tp):
    """Reassemble the chunk-level gradient dict from the per-(rank, seg)
    pieces: glue grads from rank 0 (replicated), wg = rank-order sum,
    experts = concat of rank slices — the trainer's combine semantics."""
    out = {}
    for k, seg in enumerate(plan):
        if seg["kind"] == "moe":
            bname = f"block{seg['block']:02d}"
            blk = out.setdefault(bname, {})
            blk["wg"] = sum(grads[r][k]["wg"] for r in range(tp))
            for key in ("w1", "b1", "w2", "b2"):
                blk[key] = jnp.concatenate(
                    [grads[r][k][key] for r in range(tp)], axis=0)
        else:
            for name, val in grads[0][k].items():
                if isinstance(val, dict):
                    out.setdefault(name, {}).update(val)
                else:
                    out[name] = val
    return out


def flatten_grad_dict(d, prefix=""):
    items = {}
    for k, v in sorted(d.items()):
        if isinstance(v, dict):
            items.update(flatten_grad_dict(v, prefix + k + "."))
        else:
            items[prefix + k] = v
    return items


def tp_configs():
    tiny = CONFIGS["tiny"]
    # v=2: every chunk carries one mid-chunk MoE; v=1: TWO MoE layers per
    # chunk, exercising the glue-between-two-combines path
    deep1 = CONFIGS["tiny-deep"]
    deep2 = dataclasses.replace(deep1, virtual_stages=2)
    # k=2 with a dropping capacity: the k-slot dispatch/weighted combine
    # must flow through the same segment calculus unchanged
    tiny_k2 = dataclasses.replace(tiny, top_k=2, capacity_factor=1.5)
    return [("tiny", tiny), ("tiny-deep-v1", deep1), ("tiny-deep-v2", deep2),
            ("tiny-k2", tiny_k2)]


@pytest.mark.parametrize("name,cfg", tp_configs())
@pytest.mark.parametrize("tp", [2])
def test_segment_plan_partitions_params(name, cfg, tp):
    """Every chunk param appears in exactly one segment (with experts
    sliced 1/tp), and the plan alternates glue/moe correctly."""
    for stage in range(cfg.stages):
        for chunk in range(cfg.virtual_stages):
            plan, cp, pdicts = seg_states(cfg, stage, chunk, tp)
            assert plan[-1]["kind"] in ("glue", "losstail")
            is_loss = (stage == cfg.stages - 1
                       and chunk == cfg.virtual_stages - 1)
            assert (plan[-1]["kind"] == "losstail") == is_loss
            mono = flatten_grad_dict(cp)
            for r in range(tp):
                seen = {}
                for k, seg in enumerate(plan):
                    flat = flatten_grad_dict(
                        pdicts[r][k],
                        f"block{seg['block']:02d}."
                        if seg["kind"] == "moe" else "")
                    dup = set(seen) & set(flat)
                    assert not dup, f"params assigned twice: {dup}"
                    seen.update(flat)
                assert set(seen) == set(mono)
                for pname, v in seen.items():
                    ref = mono[pname]
                    if pname.split(".")[-1] in ("w1", "b1", "w2", "b2") and \
                            v.shape != ref.shape:
                        assert v.shape[0] * tp == ref.shape[0], pname
                    else:
                        assert v.shape == ref.shape, pname


@pytest.mark.parametrize("name,cfg", tp_configs())
@pytest.mark.parametrize("tp", [2])
def test_segmented_forward_matches_monolithic(name, cfg, tp):
    for stage in range(cfg.stages):
        for chunk in range(cfg.virtual_stages):
            if (stage == cfg.stages - 1 and chunk == cfg.virtual_stages - 1):
                continue  # loss chunk: covered by the losstail test
            plan, cp, pdicts = seg_states(cfg, stage, chunk, tp)
            x = chunk_input(cfg, stage, chunk)
            h_ref, aux_ref = model.chunk_fwd(cp, x, cfg, stage, chunk)
            (h_seg,), aux_seg, _ = run_segmented_fwd(
                cfg, stage, chunk, tp, plan, pdicts, x)
            tol(h_seg, h_ref, f"{name} s{stage}c{chunk} fwd")
            tol(aux_seg, aux_ref, f"{name} s{stage}c{chunk} aux")


@pytest.mark.parametrize("name,cfg", tp_configs())
@pytest.mark.parametrize("tp", [2])
def test_segmented_backward_matches_monolithic(name, cfg, tp):
    """The headline calculus check: composed segment backwards (rank-order
    sums for dhgt/dwg, aux cotangent on rank 0 only, replicated glue)
    reproduce the monolithic chunk vjp — dx AND every parameter grad."""
    for stage in range(cfg.stages):
        for chunk in range(cfg.virtual_stages):
            if (stage == cfg.stages - 1 and chunk == cfg.virtual_stages - 1):
                continue
            plan, cp, pdicts = seg_states(cfg, stage, chunk, tp)
            x = chunk_input(cfg, stage, chunk)
            dh = 0.3 * jax.random.normal(
                jax.random.PRNGKey(11),
                (cfg.micro_batch, cfg.seq, cfg.hidden))
            daux = jnp.float32(cfg.aux_coef)

            (_, vjp_fn) = jax.vjp(
                lambda pp, xx: model.chunk_fwd(pp, xx, cfg, stage, chunk),
                cp, x)
            dp_ref, dx_ref = vjp_fn((dh, daux))

            _, _, stash = run_segmented_fwd(
                cfg, stage, chunk, tp, plan, pdicts, x)
            _, dx_seg, grads = run_segmented_bwd(
                cfg, stage, chunk, tp, plan, pdicts, stash, (dh, daux))
            if not (stage == 0 and chunk == 0):
                tol(dx_seg, dx_ref, f"{name} s{stage}c{chunk} dx")
            got = flatten_grad_dict(
                combine_param_grads(plan, pdicts, grads, tp))
            want = flatten_grad_dict(dp_ref)
            assert set(got) == set(want)
            for pname in want:
                tol(got[pname], want[pname],
                    f"{name} s{stage}c{chunk} grad {pname}")


@pytest.mark.parametrize("name,cfg", tp_configs())
@pytest.mark.parametrize("tp", [2])
def test_losstail_matches_monolithic_lossgrad(name, cfg, tp):
    """Loss chunk: segmented fwd + fused losstail + reverse walk vs the
    monolithic last_stage_loss vjp. The chunk's own MoE aux is added into
    aux_in host-side (the trainer's job), so the loss values must agree
    too."""
    stage, chunk = cfg.stages - 1, cfg.virtual_stages - 1
    plan, cp, pdicts = seg_states(cfg, stage, chunk, tp)
    x = chunk_input(cfg, stage, chunk)
    targets = jax.random.randint(
        jax.random.PRNGKey(5), (cfg.micro_batch, cfg.seq), 0, cfg.vocab)
    aux_in = jnp.float32(0.125)  # ring-threaded upstream aux

    loss_ref, vjp_fn = jax.vjp(
        lambda pp, xx: model.last_stage_loss(pp, xx, targets, aux_in, cfg),
        cp, x)
    dp_ref, dx_ref = vjp_fn(jnp.float32(1.0))

    _, own_aux, stash = run_segmented_fwd(
        cfg, stage, chunk, tp, plan, pdicts, x)
    loss_seg, dx_seg, grads = run_segmented_bwd(
        cfg, stage, chunk, tp, plan, pdicts, stash, None,
        targets=targets, aux_in=aux_in + own_aux)
    tol(loss_seg, loss_ref, f"{name} loss", rtol=1e-5, atol=1e-6)
    if cfg.stages > 1 or cfg.virtual_stages > 1:
        tol(dx_seg, dx_ref, f"{name} loss dx")
    got = flatten_grad_dict(combine_param_grads(plan, pdicts, grads, tp))
    want = flatten_grad_dict(dp_ref)
    assert set(got) == set(want)
    for pname in want:
        tol(got[pname], want[pname], f"{name} loss grad {pname}")
