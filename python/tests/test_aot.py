"""AOT pipeline contracts: capacity policy, HLO text properties, manifest
invariants the Rust runtime depends on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, stages
from compile.model import ModelConfig


class TestCapacityPolicy:
    def test_uncapped_when_cf_zero(self):
        cfg = ModelConfig(capacity_factor=0.0, micro_batch=2, seq=32, experts=4)
        assert cfg.capacity == cfg.tokens

    def test_cf_scales_capacity(self):
        cfg = ModelConfig(capacity_factor=2.0, micro_batch=4, seq=64, experts=8)
        # 2 * 256/8 = 64
        assert cfg.capacity == 64
        cfg1 = ModelConfig(capacity_factor=1.0, micro_batch=4, seq=64, experts=8)
        assert cfg1.capacity == 32

    def test_capacity_padded_and_bounded(self):
        cfg = ModelConfig(capacity_factor=1.0, micro_batch=1, seq=10, experts=3)
        assert cfg.capacity % 8 == 0 or cfg.capacity == cfg.tokens
        assert cfg.capacity >= 8
        big = ModelConfig(capacity_factor=100.0, micro_batch=2, seq=16, experts=2)
        assert big.capacity == big.tokens  # never exceeds token count


class TestHloText:
    """The xla_extension-0.5.1 interchange constraints (aot_recipe)."""

    @pytest.fixture(scope="class")
    def lowered_text(self):
        cfg = aot.CONFIGS["tiny"]
        params = __import__("compile.model", fromlist=["model"]).init_stage(
            jax.random.PRNGKey(0), cfg, 0)
        fn, ex, _ = stages.make_stage_fwd(cfg, 0, params)
        lowered = jax.jit(fn, keep_unused=True).lower(*ex)
        return aot.to_hlo_text(lowered), len(ex)

    def test_is_hlo_text_not_proto(self, lowered_text):
        text, _ = lowered_text
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_entry_keeps_all_params(self, lowered_text):
        """keep_unused=True: every python-side arg appears as an entry
        parameter — positional contract with the Rust runtime."""
        text, n_args = lowered_text
        import re
        entry = text[text.index("ENTRY"):]
        entry = entry[:entry.index("\n}")]
        params = set(re.findall(r"parameter\((\d+)\)", entry))
        assert len(params) == n_args

    def test_root_is_tuple(self, lowered_text):
        """return_tuple=True: rust unpacks with to_tuple()."""
        text, _ = lowered_text
        entry = text[text.index("ENTRY"):]
        assert "ROOT" in entry and "tuple(" in entry


class TestDtypeTags:
    def test_known_tags(self):
        assert aot._dtype_tag(jnp.float32) == "f32"
        assert aot._dtype_tag(jnp.int32) == "i32"

    def test_unknown_dtype_rejected(self):
        with pytest.raises(KeyError):
            aot._dtype_tag(jnp.float64)


def test_moe_rank_requires_divisible_experts():
    cfg = ModelConfig(experts=6, micro_batch=2, seq=16)
    with pytest.raises(AssertionError):
        stages.make_moe_rank(cfg, 0, 4)


def test_capacity_drops_are_rare_with_cf2():
    """With the aux loss off but random gating weights, cf=2 capacity drops
    stay under ~15% on random inputs (and fall further once the balance
    loss trains the router)."""
    from compile.kernels import gating, ref

    cfg = ModelConfig(capacity_factor=2.0, micro_batch=4, seq=64, experts=8)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (cfg.tokens, cfg.hidden))
    wg = jax.random.normal(jax.random.PRNGKey(1), (cfg.hidden, cfg.experts)) * 0.1
    probs, top1 = ref.router_ref(x, wg)
    dispatch, _, _ = gating.make_dispatch(probs, top1, cfg.experts, cfg.capacity)
    kept = float(jnp.sum(dispatch))
    drop_frac = 1.0 - kept / cfg.tokens
    assert drop_frac < 0.15, f"drop fraction {drop_frac}"
