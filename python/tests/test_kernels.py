"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes; every test asserts allclose against ref.py.
This is the CORE correctness signal for the kernel layer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Offline containers ship no hypothesis; skip this module (instead of
# failing collection) so `pytest python/tests` stays runnable everywhere.
# CI installs hypothesis and runs the full sweep.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import dense_ffn, gating, moe_ffn, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=0.5):
    return jax.random.normal(key, shape, jnp.float32) * scale


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# moe_ffn (grouped expert FFN)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    E=st.sampled_from([1, 2, 4, 8]),
    C=st.sampled_from([8, 16, 32]),
    h=st.sampled_from([8, 16, 32]),
    fmul=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
)
def test_moe_ffn_matches_ref(E, C, h, fmul, seed):
    f = h * fmul
    ks = keys(seed, 5)
    xd = rand(ks[0], (E, C, h))
    w1, b1 = rand(ks[1], (E, h, f)), rand(ks[2], (E, f), 0.1)
    w2, b2 = rand(ks[3], (E, f, h)), rand(ks[4], (E, h), 0.1)
    out = moe_ffn.moe_ffn(xd, w1, b1, w2, b2, block_c=min(C, 8))
    expect = ref.moe_ffn_ref(xd, w1, b1, w2, b2)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("block_c", [4, 8, 16, 32])
def test_moe_ffn_block_c_invariance(block_c):
    """Output must not depend on the capacity tiling."""
    E, C, h, f = 4, 32, 16, 32
    ks = keys(7, 5)
    args = (rand(ks[0], (E, C, h)), rand(ks[1], (E, h, f)),
            rand(ks[2], (E, f)), rand(ks[3], (E, f, h)), rand(ks[4], (E, h)))
    out = moe_ffn.moe_ffn(*args, block_c=block_c)
    expect = ref.moe_ffn_ref(*args)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


def test_moe_ffn_grad_matches_ref():
    """custom_vjp backward kernel vs jax-autodiff of the oracle."""
    E, C, h, f = 3, 16, 8, 16
    ks = keys(11, 6)
    args = [rand(ks[0], (E, C, h)), rand(ks[1], (E, h, f)),
            rand(ks[2], (E, f)), rand(ks[3], (E, f, h)), rand(ks[4], (E, h))]

    def loss_kernel(*a):
        return jnp.sum(jnp.sin(moe_ffn.moe_ffn(*a, block_c=8)))

    def loss_ref(*a):
        return jnp.sum(jnp.sin(ref.moe_ffn_ref(*a)))

    g_k = jax.grad(loss_kernel, argnums=tuple(range(5)))(*args)
    g_r = jax.grad(loss_ref, argnums=tuple(range(5)))(*args)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


def test_moe_ffn_zero_slab_is_bias_path():
    """Empty (zero) capacity slots still produce the FFN of zero input —
    the combine mask zeroes them later; they must not be NaN."""
    E, C, h, f = 2, 8, 8, 16
    ks = keys(13, 4)
    out = moe_ffn.moe_ffn(
        jnp.zeros((E, C, h)), rand(ks[0], (E, h, f)), rand(ks[1], (E, f)),
        rand(ks[2], (E, f, h)), rand(ks[3], (E, h)), block_c=8)
    assert np.all(np.isfinite(out))


# ---------------------------------------------------------------------------
# dense_ffn
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([8, 16, 64]),
    h=st.sampled_from([8, 32]),
    fmul=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**16),
)
def test_dense_ffn_matches_ref(t, h, fmul, seed):
    f = h * fmul
    ks = keys(seed, 5)
    args = (rand(ks[0], (t, h)), rand(ks[1], (h, f)), rand(ks[2], (f,)),
            rand(ks[3], (f, h)), rand(ks[4], (h,)))
    out = dense_ffn.dense_ffn(*args, block_t=min(t, 8))
    np.testing.assert_allclose(out, ref.dense_ffn_ref(*args),
                               rtol=1e-4, atol=1e-5)


def test_dense_ffn_grad_matches_ref():
    t, h, f = 16, 8, 16
    ks = keys(17, 5)
    args = [rand(ks[0], (t, h)), rand(ks[1], (h, f)), rand(ks[2], (f,)),
            rand(ks[3], (f, h)), rand(ks[4], (h,))]
    g_k = jax.grad(lambda *a: jnp.sum(jnp.tanh(dense_ffn.dense_ffn(*a, block_t=8))),
                   argnums=tuple(range(5)))(*args)
    g_r = jax.grad(lambda *a: jnp.sum(jnp.tanh(ref.dense_ffn_ref(*a))),
                   argnums=tuple(range(5)))(*args)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# router + dispatch
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    t=st.sampled_from([8, 32, 128]),
    h=st.sampled_from([8, 32]),
    E=st.sampled_from([2, 4, 16]),
    seed=st.integers(0, 2**16),
)
def test_router_matches_ref(t, h, E, seed):
    ks = keys(seed, 2)
    x, wg = rand(ks[0], (t, h)), rand(ks[1], (h, E))
    probs, top1 = gating.router(x, wg, block_t=min(t, 8))
    probs_r, top1_r = ref.router_ref(x, wg)
    np.testing.assert_allclose(probs, probs_r, rtol=1e-4, atol=1e-6)
    np.testing.assert_array_equal(top1, top1_r)


def test_router_probs_are_distribution():
    x, wg = rand(keys(3, 2)[0], (64, 16)), rand(keys(3, 2)[1], (16, 8))
    probs, top1 = gating.router(x, wg)
    np.testing.assert_allclose(np.sum(probs, axis=-1), 1.0, rtol=1e-5)
    assert probs.min() >= 0
    assert top1.min() >= 0 and top1.max() < 8


def test_router_grad_matches_ref():
    t, h, E = 16, 8, 4
    ks = keys(23, 2)
    x, wg = rand(ks[0], (t, h)), rand(ks[1], (h, E))
    g_k = jax.grad(lambda x_, w_: jnp.sum(gating.router(x_, w_, block_t=8)[0] ** 2),
                   argnums=(0, 1))(x, wg)
    g_r = jax.grad(lambda x_, w_: jnp.sum(ref.router_ref(x_, w_)[0] ** 2),
                   argnums=(0, 1))(x, wg)
    np.testing.assert_allclose(g_k[0], g_r[0], rtol=1e-3, atol=1e-6)
    np.testing.assert_allclose(g_k[1], g_r[1], rtol=1e-3, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    t=st.sampled_from([8, 32, 64]),
    E=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_dispatch_invariants_full_capacity(t, E, seed):
    """PPMoE's uncapped dispatch: every token lands in exactly one slot and
    slots never collide (dispatch is a partial permutation matrix)."""
    ks = keys(seed, 2)
    probs, top1 = ref.router_ref(rand(ks[0], (t, 16)), rand(ks[1], (16, E)))
    dispatch, combine, aux = gating.make_dispatch(probs, top1, E, capacity=t)
    d = np.asarray(dispatch)
    # each token routed exactly once
    np.testing.assert_allclose(d.sum(axis=(1, 2)), 1.0)
    # each (expert, slot) holds at most one token
    assert d.sum(axis=0).max() <= 1.0 + 1e-6
    # combine = dispatch * gate prob of the chosen expert
    gate = np.take_along_axis(np.asarray(probs), np.asarray(top1)[:, None], 1)[:, 0]
    np.testing.assert_allclose(np.asarray(combine).sum(axis=(1, 2)), gate,
                               rtol=1e-5)
    # aux = E·Σ mₑ·cₑ is ≈1 when balanced, can dip slightly below when the
    # soft (mₑ) and hard (cₑ) distributions disagree; it is always positive
    # and bounded by E (all mass on one expert)
    assert 0.0 < float(aux) <= E + 1e-4


def test_dispatch_capacity_drops_overflow():
    """With a tight capacity, tokens beyond C per expert are dropped, and
    dropped tokens vanish from both dispatch and combine."""
    t, E, C = 16, 2, 3
    top1 = jnp.zeros((t,), jnp.int32)  # all tokens to expert 0
    probs = jnp.full((t, E), 0.5)
    dispatch, combine, _ = gating.make_dispatch(probs, top1, E, capacity=C)
    assert float(jnp.sum(dispatch)) == C  # only C survive
    assert float(jnp.sum(dispatch[:, 1, :])) == 0  # nothing on expert 1


def test_dispatch_matches_ref():
    probs, top1 = ref.router_ref(rand(keys(29, 2)[0], (32, 8)),
                                 rand(keys(29, 2)[1], (8, 4)))
    for cap in (4, 16, 32):
        d1, c1, a1 = gating.make_dispatch(probs, top1, 4, cap)
        d2, c2, a2 = ref.make_dispatch_ref(probs, top1, 4, cap)
        np.testing.assert_allclose(d1, d2)
        np.testing.assert_allclose(c1, c2)
        np.testing.assert_allclose(a1, a2)


def test_top2_dispatch_invariants():
    t, E = 32, 4
    ks = keys(31, 2)
    probs, _ = ref.router_ref(rand(ks[0], (t, 16)), rand(ks[1], (16, E)))
    dispatch, combine, aux = gating.make_dispatch_top2(probs, E, capacity=2 * t)
    d = np.asarray(dispatch)
    # each token routed exactly twice (top-2), to two distinct experts
    np.testing.assert_allclose(d.sum(axis=(1, 2)), 2.0)
    assert d.sum(axis=0).max() <= 1.0 + 1e-6
    # combine weights per token sum to 1 (renormalized gates)
    np.testing.assert_allclose(np.asarray(combine).sum(axis=(1, 2)), 1.0,
                               rtol=1e-4)


def test_gating_determinism():
    """§3.3.3: identical inputs + weights => identical dispatch on every
    'rank'. Run the router twice and demand bit-identical outputs."""
    x, wg = rand(keys(37, 2)[0], (64, 32)), rand(keys(37, 2)[1], (32, 8))
    p1, t1 = gating.router(x, wg)
    p2, t2 = gating.router(x, wg)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


# ---------------------------------------------------------------------------
# full MoE layer oracle composition
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), E=st.sampled_from([2, 4, 8]))
def test_moe_layer_kernel_vs_oracle(seed, E):
    t, h, f = 32, 16, 32
    ks = keys(seed, 6)
    x = rand(ks[0], (t, h))
    wg = rand(ks[1], (h, E))
    w1, b1 = rand(ks[2], (E, h, f)), rand(ks[3], (E, f), 0.1)
    w2, b2 = rand(ks[4], (E, f, h)), rand(ks[5], (E, h), 0.1)
    # kernel path
    probs, top1 = gating.router(x, wg, block_t=8)
    d, c, aux = gating.make_dispatch(probs, top1, E, t)
    xd = jnp.einsum("tec,th->ech", d, x)
    yd = moe_ffn.moe_ffn(xd, w1, b1, w2, b2, block_c=8)
    y = jnp.einsum("tec,ech->th", c, yd)
    # oracle
    y_ref, aux_ref = ref.moe_layer_ref(x, wg, w1, b1, w2, b2, capacity=t)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(aux, aux_ref, rtol=1e-5)


def test_vmem_estimate_monotone():
    """Perf-model sanity: VMEM estimate grows with block size."""
    v1 = moe_ffn.vmem_bytes(32, 128, 512)
    v2 = moe_ffn.vmem_bytes(128, 128, 512)
    assert v2 > v1
    assert moe_ffn.mxu_flops_per_step(64, 128, 512) == 2 * 64 * 128 * 512 * 2
