"""Numpy twin of the top-k dispatch contract, shared by the dispatch tests.

This module is deliberately kernel-free (plain numpy, no jax import): it is
the executable statement of WHAT `compile.kernels.gating.make_dispatch_topk`
computes, written as explicit loops instead of one-hot algebra so a reader
can check the slot-assignment and drop semantics line by line. The jnp
kernel is pinned bitwise against this twin in test_topk_gating.py, and the
index-slice-vs-all-to-all property in test_tp_dispatch.py is stated over it.

Summation contract
------------------
Float addition is not associative, so "the sliced ranks equal the dense
oracle" is only a bitwise statement once the reduction order is fixed. The
contract both sides use (`fold_rank_order`): per-(token, expert)
contributions are folded from a zero accumulator in ascending expert order
WITHIN each owning rank's contiguous slice, and the per-rank partials are
folded in ascending rank order — exactly the order the live trainer's
rank-order all-reduce performs. Per-contribution values themselves are
bitwise-identical between the dense and sliced einsums because numpy's
default (unoptimized) einsum reduces the contracted slot axis in a fixed
order independent of the expert extent.
"""
import numpy as np


def topk_select(probs, k):
    """k rounds of argmax-with-masking: `jnp.top_k` first-occurrence tie
    semantics (equal scores are taken in ascending expert order)."""
    masked = probs.astype(np.float32).copy()
    t = probs.shape[0]
    idx = np.zeros((t, k), np.int64)
    for lvl in range(k):
        idx[:, lvl] = masked.argmax(-1)
        masked[np.arange(t), idx[:, lvl]] = -np.inf
    return idx


def topk_gates(probs, idx):
    """Gate weights for the selected experts: raw top-1 probability at
    k = 1, renormalized over the k winners (denom floored at 1e-9,
    GShard style) at k > 1 — same branch structure as the jnp kernel."""
    g = np.take_along_axis(probs.astype(np.float32), idx, axis=1)
    if idx.shape[1] == 1:
        return g
    denom = np.maximum(g.sum(-1, keepdims=True, dtype=np.float32),
                       np.float32(1e-9))
    return (g / denom).astype(np.float32)


def make_dispatch_topk_np(idx, gates, experts, capacity):
    """Level-major slot assignment with capacity drops, written as loops.

    Level 0 (every token's first choice) fills expert slabs first, scanning
    tokens in order; level i continues from a per-expert base equal to the
    count of ALL prior-level choices — dropped ones included, matching the
    kernel's `base += sum(onehot)` which never subtracts drops. A choice
    whose position reaches `capacity` is dropped; the token's other
    choices survive independently.
    """
    t, k = idx.shape
    dispatch = np.zeros((t, experts, capacity), np.float32)
    combine = np.zeros((t, experts, capacity), np.float32)
    chosen = np.zeros(experts, np.int64)  # all prior-level choices, incl. dropped
    for lvl in range(k):
        lvl_fill = np.zeros(experts, np.int64)
        for tok in range(t):
            e = idx[tok, lvl]
            pos = chosen[e] + lvl_fill[e]
            lvl_fill[e] += 1
            if pos < capacity:
                dispatch[tok, e, pos] = 1.0
                combine[tok, e, pos] = gates[tok, lvl]
        chosen += lvl_fill
    return dispatch, combine


def expert_fn(xd, w):
    """Per-expert linear stand-in for the expert FFN: xd (E, C, h) @ w."""
    return np.einsum("ech,eho->eco", xd, w).astype(np.float32)


def expert_contribs(x, dispatch, combine, w):
    """Per-(token, expert) output contributions, reduction over slots only.

    Keeping the expert axis un-reduced is what lets the caller apply the
    summation contract explicitly: `np.einsum("tec,eco->teo")` reduces each
    expert's slot axis independently, so contrib[:, e] is bitwise the same
    whether computed from the full (t, E, C) tensors or from any slice
    containing expert e.
    """
    xd = np.einsum("tec,th->ech", dispatch, x).astype(np.float32)
    yd = expert_fn(xd, w)
    return np.einsum("tec,eco->teo", combine, yd).astype(np.float32)


def fold_rank_order(contrib, tp):
    """THE summation contract (see module docstring): ascending experts
    within each rank's contiguous slice, then ascending ranks."""
    t, E, h = contrib.shape
    n_loc = E // tp
    total = None
    for r in range(tp):
        part = np.zeros((t, h), np.float32)
        for e in range(r * n_loc, (r + 1) * n_loc):
            part = part + contrib[:, e]
        total = part if total is None else total + part
    return total


def all_to_all_oracle_topk(x, idx, gates, w, experts, capacity, tp):
    """DPMoE semantics: dispatch every token's k copies to the global
    expert buffers (1st all-to-all), compute every expert, gather each
    token's gate-weighted results back (2nd all-to-all). Dense: every
    einsum sees the full (t, E, C) tensors and the full weight stack; the
    final reduction follows the shared summation contract."""
    dispatch, combine = make_dispatch_topk_np(idx, gates, experts, capacity)
    return fold_rank_order(expert_contribs(x, dispatch, combine, w), tp)


def index_slice_ranks_topk(x, idx, gates, w, experts, capacity, tp):
    """PPMoE semantics: every rank derives the identical dispatch order,
    index-slices its E/tp local experts (zero wire bytes), computes a
    partial from ONLY its slice of tensors and weights, and the partials
    are summed in rank order (the single inner-node all-reduce)."""
    dispatch, combine = make_dispatch_topk_np(idx, gates, experts, capacity)
    n_loc = experts // tp
    t = x.shape[0]
    o = w.shape[2]
    total = None
    for r in range(tp):
        lo = r * n_loc
        contrib = expert_contribs(
            x, dispatch[:, lo:lo + n_loc], combine[:, lo:lo + n_loc],
            w[lo:lo + n_loc])
        part = np.zeros((t, o), np.float32)
        for e in range(n_loc):
            part = part + contrib[:, e]
        total = part if total is None else total + part
    return total


def softmax_np(logits):
    """Row-stable softmax in float32 (numpy twin of the router's score)."""
    m = logits.max(-1, keepdims=True)
    e = np.exp(logits - m)
    return (e / e.sum(-1, keepdims=True)).astype(np.float32)
