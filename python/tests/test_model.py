"""L2 correctness: stage composition, TP decomposition, grad flow, AOT.

The pipeline invariant tested here is the paper's §3.3.6: stage-wise
composition with threaded aux must equal the single-shot full model, and
the TP×EP rank partials must sum to the monolithic MoE layer.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, stages
from compile.kernels import ref
from compile.model import ModelConfig

CFG = ModelConfig(vocab=64, hidden=32, ffn=64, layers=2, heads=2,
                  experts=4, seq=16, micro_batch=2, stages=2,
                  block_c=16, block_t=32)


@pytest.fixture(scope="module")
def params():
    return model.init_all(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def batch():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    tokens = jax.random.randint(k1, (CFG.micro_batch, CFG.seq), 0, CFG.vocab)
    targets = jax.random.randint(k2, (CFG.micro_batch, CFG.seq), 0, CFG.vocab)
    return tokens, targets


def test_stage_composition_equals_full(params, batch):
    tokens, targets = batch
    h, aux = model.stage_fwd(params[0], tokens, CFG, 0)
    loss_pipe = model.last_stage_loss(params[1], h, targets, aux, CFG)
    loss_full = model.full_loss(params, tokens, targets, CFG)
    np.testing.assert_allclose(float(loss_pipe), float(loss_full), rtol=1e-6)


def test_stagewise_grads_equal_full_grads(params, batch):
    """Pipeline backward (manual chaining of stage vjps) == full jax.grad."""
    tokens, targets = batch

    # full-model reference
    loss_full, g_full = jax.value_and_grad(
        lambda ps: model.full_loss(ps, tokens, targets, CFG))(params)

    # stage-wise: fwd0 -> lossgrad1 -> bwd0
    h, aux = model.stage_fwd(params[0], tokens, CFG, 0)
    (loss, vjp1) = jax.vjp(
        lambda p, x: model.last_stage_loss(p, x, targets, aux, CFG),
        params[1], h)
    dp1, dh = vjp1(jnp.float32(1.0))
    # aux cotangent: d loss / d aux = aux_coef
    _, vjp0 = jax.vjp(lambda p: model.stage_fwd(p, tokens, CFG, 0), params[0])
    (dp0,) = vjp0((dh, jnp.float32(CFG.aux_coef)))

    np.testing.assert_allclose(float(loss), float(loss_full), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(dp0),
                    jax.tree_util.tree_leaves(g_full[0])):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(dp1),
                    jax.tree_util.tree_leaves(g_full[1])):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=1e-6)


@pytest.mark.parametrize("tp", [1, 2, 4])
def test_tp_rank_partials_sum_to_single(params, tp):
    """§3.3.2-3.3.4: rank partial outputs all-reduce(sum) to the monolithic
    layer, for any TP degree dividing E."""
    blk = params[1]["block00"]  # layer index 1 => MoE
    x = jax.random.normal(jax.random.PRNGKey(3), (CFG.tokens, CFG.hidden))
    y_full, aux_full = model.moe_layer_single(
        x, blk["wg"], blk["w1"], blk["b1"], blk["w2"], blk["b2"], CFG)
    N = CFG.experts // tp
    acc = np.zeros_like(np.asarray(y_full))
    for r in range(tp):
        lo = r * N
        yp, auxp = model.moe_rank_partial(
            x, blk["wg"], blk["w1"][lo:lo + N], blk["b1"][lo:lo + N],
            blk["w2"][lo:lo + N], blk["b2"][lo:lo + N], r, tp, CFG)
        acc += np.asarray(yp)
        # every rank computes the identical aux (identical gating)
        np.testing.assert_allclose(float(auxp), float(aux_full), rtol=1e-5)
    np.testing.assert_allclose(acc, y_full, rtol=1e-4, atol=1e-5)


def test_loss_decreases_with_sgd(params, batch):
    """Trainability smoke: a few full-batch SGD steps reduce the loss.

    lr = 0.1, not 0.5: at 0.5 this seed's trajectory overshoots (loss
    4.165 -> 4.341 after 5 steps) — the historic seed failure both PR 1
    and PR 2 shipped around. The test guards trainability, not a specific
    step size; 0.1 converges with a wide margin (4.165 -> ~3.58) and is
    robust across nearby seeds.
    """
    tokens, targets = batch
    ps = params
    lossgrad = jax.jit(jax.value_and_grad(
        lambda p: model.full_loss(p, tokens, targets, CFG)))
    l0, _ = lossgrad(ps)
    for _ in range(5):
        l, g = lossgrad(ps)
        ps = jax.tree_util.tree_map(lambda p, gg: p - 0.1 * gg, ps, g)
    l1, _ = lossgrad(ps)
    assert float(l1) < float(l0)


def test_moe_layer_capacity_equivalence(params):
    """C = tokens (ours) vs C = 2*tokens: identical output — full capacity
    really is 'uncapped' (§4.1)."""
    blk = params[1]["block00"]
    x = jax.random.normal(jax.random.PRNGKey(5), (CFG.tokens, CFG.hidden))
    y1, _ = ref.moe_layer_ref(x, blk["wg"], blk["w1"], blk["b1"], blk["w2"],
                              blk["b2"], capacity=CFG.tokens)
    y2, _ = ref.moe_layer_ref(x, blk["wg"], blk["w1"], blk["b1"], blk["w2"],
                              blk["b2"], capacity=2 * CFG.tokens)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


def test_flatten_params_deterministic(params):
    n1, l1, _ = stages.flatten_params(params[0])
    n2, l2, _ = stages.flatten_params(params[0])
    assert n1 == n2
    assert all(a.shape == b.shape for a, b in zip(l1, l2))
    # names are unique and dot-joined
    assert len(set(n1)) == len(n1)
    assert all("." in n or n in ("tok_emb", "pos_emb") for n in n1)


def test_stage0_bwd_artifact_shapes(params):
    """make_stage_bwd returns one grad per param (plus dx for stage>0)."""
    fn, ex, names = stages.make_stage_bwd(CFG, 0, params[0])
    outs = jax.eval_shape(fn, *ex)
    assert len(jax.tree_util.tree_leaves(outs)) == len(names)
    fn1, ex1, names1 = stages.make_stage_bwd(CFG, 1, params[1])
    outs1 = jax.eval_shape(fn1, *ex1)
    assert len(jax.tree_util.tree_leaves(outs1)) == len(names1) + 1  # + dx


def test_aot_export_tiny(tmp_path):
    """End-to-end AOT smoke: export tiny config, check manifest + bins."""
    import json

    from compile import aot
    out = str(tmp_path / "arts")
    aot.export("tiny", out, tp=2, seed=0, include_full=False)
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["config_name"] == "tiny"
    assert len(m["stages"]) == 2
    for name, art in m["artifacts"].items():
        path = os.path.join(out, art["file"])
        assert os.path.exists(path), name
        assert art["inputs"] and art["outputs"]
    for st_entry in m["stages"]:
        binpath = os.path.join(out, st_entry["bin"])
        assert os.path.getsize(binpath) == st_entry["total_bytes"]
        # offsets are contiguous
        off = 0
        for p in st_entry["params"]:
            assert p["offset"] == off
            off += p["numel"] * 4


# ---------------------------------------------------------------------------
# Interleaved virtual-stage chunking (docs/schedules.md)
# ---------------------------------------------------------------------------

CFG_V2 = ModelConfig(vocab=64, hidden=32, ffn=64, layers=4, heads=2,
                     experts=4, seq=16, micro_batch=2, stages=2,
                     virtual_stages=2, block_c=16, block_t=32)


def test_init_chunks_v1_bitwise_matches_init_all():
    """virtual_stages == 1: the chunked init is the plain init, bitwise."""
    key = jax.random.PRNGKey(0)
    plain = model.init_all(key, CFG)
    chunked = model.init_all_chunks(key, CFG)
    assert len(chunked) == CFG.stages and all(len(c) == 1 for c in chunked)
    for s in range(CFG.stages):
        pa = jax.tree_util.tree_leaves_with_path(plain[s])
        pb = jax.tree_util.tree_leaves_with_path(chunked[s][0])
        assert len(pa) == len(pb)
        for (ka, a), (kb, b) in zip(pa, pb):
            assert ka == kb
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_layer_partition():
    """Chunks partition the layer range: virtual stage V = c*p + s owns
    [V*n, (V+1)*n) — non-contiguous per physical stage."""
    cfg = CFG_V2
    n = cfg.layers // cfg.num_virtual
    covered = []
    for c in range(cfg.virtual_stages):
        for s in range(cfg.stages):
            v_idx = c * cfg.stages + s
            covered += list(range(v_idx * n, (v_idx + 1) * n))
    assert sorted(covered) == list(range(cfg.layers))
    # stage 0 at v=2, p=2 owns layers {0} and {2} — not contiguous
    s0 = [c * cfg.stages * n + 0 for c in range(cfg.virtual_stages)]
    assert s0 == [0, 2]


def test_chunk_ring_composition_equals_full_loss():
    """Chaining chunk_fwd around the virtual ring (with the wrap-around
    edges the live trainer implements as p2p channels) + the loss head
    equals the single-shot full_loss_chunks."""
    cfg = CFG_V2
    cp = model.init_all_chunks(jax.random.PRNGKey(2), cfg)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    tokens = jax.random.randint(k1, (cfg.micro_batch, cfg.seq), 0, cfg.vocab)
    targets = jax.random.randint(k2, (cfg.micro_batch, cfg.seq), 0, cfg.vocab)

    h, aux = tokens, jnp.float32(0.0)
    for v_idx in range(cfg.num_virtual - 1):
        s, c = v_idx % cfg.stages, v_idx // cfg.stages
        h, a = model.chunk_fwd(cp[s][c], h, cfg, s, c)
        aux = aux + a
    loss_ring = model.last_stage_loss(cp[-1][-1], h, targets, aux, cfg)
    loss_full = model.full_loss_chunks(cp, tokens, targets, cfg)
    np.testing.assert_allclose(float(loss_ring), float(loss_full), rtol=1e-6)


def test_chunkwise_grads_equal_full_grads():
    """Interleaved §3.3.6: manually chaining chunk vjps around the ring —
    exactly what the interleaved trainer executes — must equal the
    single-shot jax.grad of full_loss_chunks."""
    cfg = CFG_V2
    cp = model.init_all_chunks(jax.random.PRNGKey(2), cfg)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    tokens = jax.random.randint(k1, (cfg.micro_batch, cfg.seq), 0, cfg.vocab)
    targets = jax.random.randint(k2, (cfg.micro_batch, cfg.seq), 0, cfg.vocab)

    loss_full, g_full = jax.value_and_grad(
        lambda ps: model.full_loss_chunks(ps, tokens, targets, cfg))(cp)

    # forward sweep in ring order, stashing inputs
    order = [(v % cfg.stages, v // cfg.stages) for v in range(cfg.num_virtual)]
    xs, h, aux = [], tokens, jnp.float32(0.0)
    for (s, c) in order[:-1]:
        xs.append(h)
        h, a = model.chunk_fwd(cp[s][c], h, cfg, s, c)
        aux = aux + a
    # loss chunk: fused fwd+loss vjp
    (s_l, c_l) = order[-1]
    loss, vjp_loss = jax.vjp(
        lambda p, x: model.last_stage_loss(p, x, targets, aux, cfg),
        cp[s_l][c_l], h)
    np.testing.assert_allclose(float(loss), float(loss_full), rtol=1e-6)
    dp_last, dh = vjp_loss(jnp.float32(1.0))
    grads = {order[-1]: dp_last}
    # backward sweep in reverse ring order, threading dy and the constant
    # aux cotangent (the trainer's daux input)
    for (s, c), x in zip(reversed(order[:-1]), reversed(xs)):
        _, vjp_fn = jax.vjp(
            lambda p, xx, s=s, c=c: model.chunk_fwd(p, xx, cfg, s, c),
            cp[s][c], x)
        dp, dh = vjp_fn((dh, jnp.float32(cfg.aux_coef)))
        grads[(s, c)] = dp
    for (s, c) in order:
        for a, b in zip(jax.tree_util.tree_leaves(grads[(s, c)]),
                        jax.tree_util.tree_leaves(g_full[s][c])):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=1e-6)


def test_aot_export_chunked(tmp_path):
    """AOT smoke at virtual_stages = 2: per-chunk artifacts + chunks table."""
    import json

    from compile import aot
    out = str(tmp_path / "arts_v2")
    aot.export("tiny-deep", out, tp=2, seed=0, include_full=False, virtual=2)
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["config"]["virtual_stages"] == 2
    assert len(m["chunks"]) == 2 and all(len(c) == 2 for c in m["chunks"])
    # chunk param counts partition each stage's param list
    for st_entry, chunk_row in zip(m["stages"], m["chunks"]):
        assert sum(c["params"] for c in chunk_row) == len(st_entry["params"])
    # the loss chunk is fused into lossgrad; every other chunk has fwd+bwd
    assert m["chunks"][-1][-1]["fwd"] is None
    assert m["chunks"][-1][-1]["bwd"] == "lossgrad"
    for name in ("stage0_chunk0_fwd", "stage0_chunk1_fwd", "stage1_chunk0_bwd",
                 "lossgrad", "loss_eval"):
        assert name in m["artifacts"], name
        assert os.path.exists(os.path.join(out, m["artifacts"][name]["file"]))
    # chunk 1 of stage 0 takes wrap-around ACTIVATIONS, not tokens
    c1 = m["artifacts"]["stage0_chunk1_fwd"]
    assert c1["inputs"][-1]["dtype"] == "f32"
    c0 = m["artifacts"]["stage0_chunk0_fwd"]
    assert c0["inputs"][-1]["dtype"] == "i32"
