"""Flat-signature stage functions for AOT lowering.

HLO artifacts are shape-monomorphic and take flat argument lists, so this
module adapts the pytree-based model functions of `model.py` into functions
over (param_0, ..., param_k, x, ...) suitable for `jax.jit(...).lower()`.
Parameter order is the deterministic pytree flattening order (sorted dict
keys), recorded in the manifest so the Rust runtime can address tensors by
name.

Backward functions are *recompute-based*: `stage_bwd(params, x, dy, daux)`
re-runs the stage forward inside `jax.vjp` and returns (dx, dparams). This
keeps every artifact a pure function with flat array ins/outs — no residual
pytrees cross the Rust boundary — at the cost of one extra forward per
backward, exactly like Megatron's full activation recomputation (Chen et
al. 2016, cited by the paper §2).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import model
from .model import ModelConfig


def flatten_params(params: dict[str, Any]):
    """Deterministic (names, leaves, treedef) for a stage's param dict."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    names = [
        ".".join(str(k.key) for k in path) for path, _ in paths
    ]
    return names, leaves, treedef


def unflatten_params(treedef, leaves):
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Per-stage artifact factories. Each returns (fn, example_args) where fn has
# a flat signature ready for jax.jit(fn).lower(*example_args).
# ---------------------------------------------------------------------------


def _example_chunk_x(cfg: ModelConfig, stage: int, chunk: int):
    if stage == 0 and chunk == 0:
        return jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return jnp.zeros((cfg.micro_batch, cfg.seq, cfg.hidden), jnp.float32)


def _example_x(cfg: ModelConfig, stage: int):
    return _example_chunk_x(cfg, stage, 0)


def make_chunk_fwd(cfg: ModelConfig, stage: int, chunk: int,
                   params: dict[str, Any]):
    """chunk_fwd: (params..., x) -> (act, aux).

    Only virtual stage 0 (= stage 0, chunk 0) takes int tokens; chunk c > 0
    of stage 0 takes the wrap-around activations from the last stage.
    """
    names, leaves, treedef = flatten_params(params)

    def fn(*args):
        p = unflatten_params(treedef, list(args[:-1]))
        return model.chunk_fwd(p, args[-1], cfg, stage, chunk)

    return fn, [*leaves, _example_chunk_x(cfg, stage, chunk)], names


def make_stage_fwd(cfg: ModelConfig, stage: int, params: dict[str, Any]):
    """stage_fwd: (params..., x) -> (act, aux) — single-chunk view."""
    return make_chunk_fwd(cfg, stage, 0, params)


def make_chunk_bwd(cfg: ModelConfig, stage: int, chunk: int,
                   params: dict[str, Any]):
    """chunk_bwd: (params..., x, dy, daux) -> (dx?, dparams...).

    dx is emitted for every virtual stage except 0 (whose input is int
    tokens — nothing upstream consumes a cotangent for it).
    """
    names, leaves, treedef = flatten_params(params)

    def fn(*args):
        p_leaves, x, dy, daux = list(args[:-3]), args[-3], args[-2], args[-1]
        p = unflatten_params(treedef, p_leaves)
        _, vjp_fn = jax.vjp(
            lambda pp, xx: model.chunk_fwd(pp, xx, cfg, stage, chunk), p, x
        )
        dp, dx = vjp_fn((dy, daux))
        dp_leaves = jax.tree_util.tree_leaves(dp)
        if stage == 0 and chunk == 0:
            return tuple(dp_leaves)
        return (dx, *dp_leaves)

    dy = jnp.zeros((cfg.micro_batch, cfg.seq, cfg.hidden), jnp.float32)
    daux = jnp.float32(0.0)
    return fn, [*leaves, _example_chunk_x(cfg, stage, chunk), dy, daux], names


def make_stage_bwd(cfg: ModelConfig, stage: int, params: dict[str, Any]):
    """stage_bwd: (params..., x, dy, daux) -> (dx?, dparams...)."""
    return make_chunk_bwd(cfg, stage, 0, params)


def make_last_stage_lossgrad(cfg: ModelConfig, params: dict[str, Any]):
    """lossgrad: (params..., x, targets, aux_in) -> (loss, dx, dparams...).

    Covers the LAST VIRTUAL CHUNK (stage p−1, chunk v−1) — the whole last
    stage when virtual_stages == 1. The cotangent wrt aux_in is the
    constant cfg.aux_coef; the L3 trainer passes it straight to earlier
    chunks' `daux`, so it is not re-emitted.
    """
    names, leaves, treedef = flatten_params(params)

    def fn(*args):
        p_leaves, x, tgt, aux_in = list(args[:-3]), args[-3], args[-2], args[-1]
        p = unflatten_params(treedef, p_leaves)
        loss, vjp_fn = jax.vjp(
            lambda pp, xx: model.last_stage_loss(pp, xx, tgt, aux_in, cfg), p, x
        )
        dp, dx = vjp_fn(jnp.float32(1.0))
        return (loss, dx, *jax.tree_util.tree_leaves(dp))

    x = _example_chunk_x(cfg, cfg.stages - 1, cfg.virtual_stages - 1)
    tgt = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return fn, [*leaves, x, tgt, jnp.float32(0.0)], names


def make_last_stage_loss(cfg: ModelConfig, params: dict[str, Any]):
    """Eval-only loss: (params..., x, targets, aux_in) -> (loss,)."""
    names, leaves, treedef = flatten_params(params)

    def fn(*args):
        p_leaves, x, tgt, aux_in = list(args[:-3]), args[-3], args[-2], args[-1]
        p = unflatten_params(treedef, p_leaves)
        return (model.last_stage_loss(p, x, tgt, aux_in, cfg),)

    x = _example_chunk_x(cfg, cfg.stages - 1, cfg.virtual_stages - 1)
    tgt = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return fn, [*leaves, x, tgt, jnp.float32(0.0)], names


def make_full_lossgrad(cfg: ModelConfig, all_params: list[dict[str, Any]]):
    """Whole-model single-shot (loss, grads...) — the §3.3.6 functional-
    equivalence reference the pipelined trainer is verified against."""
    flat = [flatten_params(p) for p in all_params]
    counts = [len(f[1]) for f in flat]

    def fn(*args):
        off, ps = 0, []
        for (names, _, treedef), n in zip(flat, counts):
            ps.append(unflatten_params(treedef, list(args[off:off + n])))
            off += n
        tokens, targets = args[-2], args[-1]
        loss, grads = jax.value_and_grad(
            lambda pp: model.full_loss(pp, tokens, targets, cfg)
        )(ps)
        return (loss, *jax.tree_util.tree_leaves(grads))

    leaves = [leaf for f in flat for leaf in f[1]]
    names = [f"stage{s}.{n}" for s, f in enumerate(flat) for n in f[0]]
    tokens = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    targets = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return fn, [*leaves, tokens, targets], names


def make_full_lossgrad_chunks(cfg: ModelConfig,
                              chunk_params: list[list[dict[str, Any]]]):
    """Whole-model single-shot (loss, grads...) over [stage][chunk]
    parameters — the interleaved counterpart of `make_full_lossgrad`.
    Inputs and emitted gradients are both in stage-major (stage, chunk)
    order, matching the per-stage bin layout."""
    S, V = cfg.stages, cfg.virtual_stages
    flat = [[flatten_params(chunk_params[s][c]) for c in range(V)]
            for s in range(S)]

    def fn(*args):
        off = 0
        ps: list[list[Any]] = []
        for s in range(S):
            row = []
            for c in range(V):
                _, leaves, treedef = flat[s][c]
                n = len(leaves)
                row.append(unflatten_params(treedef, list(args[off:off + n])))
                off += n
            ps.append(row)
        tokens, targets = args[-2], args[-1]
        loss, grads = jax.value_and_grad(
            lambda pp: model.full_loss_chunks(pp, tokens, targets, cfg)
        )(ps)
        return (loss, *jax.tree_util.tree_leaves(grads))

    leaves = [leaf for row in flat for f in row for leaf in f[1]]
    names = [
        f"stage{s}.chunk{c}.{n}"
        for s in range(S) for c in range(V) for n in flat[s][c][0]
    ]
    tokens = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    targets = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return fn, [*leaves, tokens, targets], names


# ---------------------------------------------------------------------------
# TP-pipeline segment artifacts (the live trainer's tp > 1 execution plan)
# ---------------------------------------------------------------------------
#
# A chunk with MoE layers cannot run expert-sharded as ONE artifact: the
# combined (all-reduced) MoE output feeds the next block. So the tp export
# cuts each chunk at its MoE layers into an alternating sequence of
# replicated "glue" segments and per-rank "moe" segments, with the trainer
# performing the inner-node all-reduce at each cut (forward: the partial
# outputs; backward: the partial d(hgt) cotangents and, at step end, the
# partial gating-weight gradients). Gradient classes per parameter:
#
#   rep  — glue params: every rank computes the identical (true) gradient,
#          because all glue inputs AND cotangents are replicated once the
#          backward all-reduces d(hgt);
#   sum  — the gating weights wg: each rank's gradient only sees its local
#          experts' dispatch slice (rank 0 additionally carries the aux-loss
#          path), so the true gradient is the rank-order sum;
#   loc  — the expert weights w1/b1/w2/b2: sliced per rank, local gradient
#          is already exact.

TP_ATTN_KEYS = ("ln1_g", "ln1_b", "wqkv", "bqkv", "wo", "bo", "ln2_g", "ln2_b")
TP_MOE_KEYS = ("wg", "w1", "b1", "w2", "b2")


def tp_chunk_plan(cfg: ModelConfig, stage: int, chunk: int) -> list[dict]:
    """The segment sequence of one (stage, chunk) under the tp export.

    Glue segments carry ``blocks`` (a half-open range of fully-contained
    dense blocks), ``pre_moe`` (the MoE block whose attention + pre-MoE LN
    close the segment, or None) and ``post_moe`` (whether the segment opens
    with the residual add of a preceding combine). The final segment of the
    loss chunk is the fused ``losstail`` (loss head + backward of the tail,
    mirroring the monolithic ``lossgrad``)."""
    n = cfg.layers // cfg.num_virtual
    v_idx = chunk * cfg.stages + stage
    is_loss = stage == cfg.stages - 1 and chunk == cfg.virtual_stages - 1
    moes = [j for j in range(n) if cfg.is_moe_layer(v_idx * n + j)]
    segs: list[dict] = []
    start = 0
    for k, j in enumerate(moes):
        segs.append({"kind": "glue", "blocks": (start, j), "pre_moe": j,
                     "post_moe": k > 0})
        segs.append({"kind": "moe", "block": j})
        start = j + 1
    segs.append({"kind": "losstail" if is_loss else "glue",
                 "blocks": (start, n), "pre_moe": None,
                 "post_moe": bool(moes)})
    return segs


def tp_segment_params(chunk_params: dict[str, Any], seg: dict,
                      cfg: ModelConfig, rank: int, tp: int,
                      first: bool, v_idx: int) -> dict[str, Any]:
    """The parameter sub-dict one segment owns on one rank.

    Partitions the chunk's params exactly: dense blocks go whole into their
    glue segment, an MoE block splits into attention/LN keys (glue) and
    gating + rank-sliced expert keys (moe), embeddings ride with the
    chunk-opening segment and the loss head with the losstail."""
    if seg["kind"] == "moe":
        bp = chunk_params[f"block{seg['block']:02d}"]
        assert cfg.experts % tp == 0, (cfg.experts, tp)
        n_loc = cfg.experts // tp
        lo = rank * n_loc
        return {
            "wg": bp["wg"],
            "w1": bp["w1"][lo:lo + n_loc], "b1": bp["b1"][lo:lo + n_loc],
            "w2": bp["w2"][lo:lo + n_loc], "b2": bp["b2"][lo:lo + n_loc],
        }
    p: dict[str, Any] = {}
    if first and v_idx == 0:
        p["tok_emb"] = chunk_params["tok_emb"]
        p["pos_emb"] = chunk_params["pos_emb"]
    for j in range(*seg["blocks"]):
        p[f"block{j:02d}"] = chunk_params[f"block{j:02d}"]
    if seg["pre_moe"] is not None:
        bp = chunk_params[f"block{seg['pre_moe']:02d}"]
        p[f"block{seg['pre_moe']:02d}"] = {k: bp[k] for k in TP_ATTN_KEYS}
    if seg["kind"] == "losstail":
        p["lnf_g"] = chunk_params["lnf_g"]
        p["lnf_b"] = chunk_params["lnf_b"]
        p["w_out"] = chunk_params["w_out"]
    return p


def tp_seg_grad_class(seg: dict, names: list[str]) -> list[str]:
    """Per-parameter gradient class tags ("rep" | "sum" | "loc") in the
    segment's flattened name order — the manifest contract the trainer's
    tp gradient combine and clip-norm decomposition key off."""
    if seg["kind"] != "moe":
        return ["rep"] * len(names)
    return ["sum" if n == "wg" else "loc" for n in names]


def _glue_example_ins(cfg: ModelConfig, stage: int, chunk: int,
                      first: bool, post_moe: bool) -> list:
    act = jnp.zeros((cfg.micro_batch, cfg.seq, cfg.hidden), jnp.float32)
    if post_moe:
        return [act, act]
    if first:
        return [_example_chunk_x(cfg, stage, chunk)]
    return [act]


def make_tp_glue_fwd(cfg: ModelConfig, stage: int, chunk: int, seg: dict,
                     params: dict[str, Any], first: bool):
    """glue_fwd: (params..., x[, y]) -> (h,) | (x_res, hgt)."""
    names, leaves, treedef = flatten_params(params)
    blocks, pre, post = seg["blocks"], seg["pre_moe"], seg["post_moe"]
    nx = 2 if post else 1

    def fn(*args):
        p = unflatten_params(treedef, list(args[:-nx]))
        return model.tp_glue_fwd(p, args[-nx:], cfg, stage, chunk, blocks,
                                 pre, post, first)

    ex = _glue_example_ins(cfg, stage, chunk, first, post)
    return fn, [*leaves, *ex], names


def make_tp_glue_bwd(cfg: ModelConfig, stage: int, chunk: int, seg: dict,
                     params: dict[str, Any], first: bool):
    """glue_bwd: (params..., x[, y], d_out...) -> (dx[, dy], dparams...).

    Recompute-based like every other backward artifact; `d_out` mirrors the
    forward outputs ((dh,) or (dx_res, dhgt) — the latter ALREADY summed
    across ranks by the trainer, which is what makes the replicated-grad
    class exact). The chunk-opening segment of virtual stage 0 consumes int
    tokens and emits no dx."""
    names, leaves, treedef = flatten_params(params)
    blocks, pre, post = seg["blocks"], seg["pre_moe"], seg["post_moe"]
    nx = 2 if post else 1
    nout = 2 if pre is not None else 1
    k = len(leaves)
    tokens_in = first and stage == 0 and chunk == 0 and not post

    def fn(*args):
        p = unflatten_params(treedef, list(args[:k]))
        xs = args[k:k + nx]
        cts = tuple(args[k + nx:k + nx + nout])
        _, vjp_fn = jax.vjp(
            lambda pp, *xx: model.tp_glue_fwd(pp, xx, cfg, stage, chunk,
                                              blocks, pre, post, first),
            p, *xs,
        )
        res = vjp_fn(cts)
        dp_leaves = jax.tree_util.tree_leaves(res[0])
        if tokens_in:
            return tuple(dp_leaves)
        return (*res[1:], *dp_leaves)

    ex_in = _glue_example_ins(cfg, stage, chunk, first, post)
    act = jnp.zeros((cfg.micro_batch, cfg.seq, cfg.hidden), jnp.float32)
    ex_ct = [act] * nout
    return fn, [*leaves, *ex_in, *ex_ct], names


def make_tp_moe_seg_fwd(cfg: ModelConfig, rank: int, tp: int,
                        params: dict[str, Any]):
    """moe_fwd (one rank): (params..., hgt) -> (y_partial, aux)."""
    names, leaves, treedef = flatten_params(params)

    def fn(*args):
        p = unflatten_params(treedef, list(args[:-1]))
        return model.tp_moe_fwd(p, args[-1], cfg, rank, tp)

    hgt = jnp.zeros((cfg.micro_batch, cfg.seq, cfg.hidden), jnp.float32)
    return fn, [*leaves, hgt], names


def make_tp_moe_seg_bwd(cfg: ModelConfig, rank: int, tp: int,
                        params: dict[str, Any]):
    """moe_bwd (one rank): (params..., hgt, dy, daux) -> (dhgt, dparams...).

    `dhgt` and `dwg` are rank-partial (the trainer sums them in rank
    order); expert grads are exact locally. The trainer passes the aux
    cotangent `daux = aux_coef` to rank 0 only and 0.0 elsewhere, so the
    replicated aux path is counted exactly once in the rank sum."""
    names, leaves, treedef = flatten_params(params)

    def fn(*args):
        p = unflatten_params(treedef, list(args[:-3]))
        hgt, dy, daux = args[-3], args[-2], args[-1]
        _, vjp_fn = jax.vjp(
            lambda pp, xx: model.tp_moe_fwd(pp, xx, cfg, rank, tp), p, hgt
        )
        dp, dhgt = vjp_fn((dy, daux))
        return (dhgt, *jax.tree_util.tree_leaves(dp))

    act = jnp.zeros((cfg.micro_batch, cfg.seq, cfg.hidden), jnp.float32)
    return fn, [*leaves, act, act, jnp.float32(0.0)], names


def make_tp_losstail(cfg: ModelConfig, stage: int, chunk: int, seg: dict,
                     params: dict[str, Any], first: bool):
    """losstail (fused fwd+loss+bwd, replicated):
    (params..., x[, y], targets, aux_in) -> (loss, dx[, dy], dparams...).

    The tp counterpart of `lossgrad`, covering only the replicated tail of
    the loss chunk; `aux_in` already includes this chunk's own MoE aux
    (trainer-added). The aux_in cotangent is the constant aux_coef, not
    re-emitted — same convention as `make_last_stage_lossgrad`."""
    names, leaves, treedef = flatten_params(params)
    blocks, post = seg["blocks"], seg["post_moe"]
    nx = 2 if post else 1
    k = len(leaves)
    tokens_in = first and stage == 0 and chunk == 0 and not post

    def fn(*args):
        p = unflatten_params(treedef, list(args[:k]))
        xs = args[k:k + nx]
        tgt, aux_in = args[k + nx], args[k + nx + 1]
        loss, vjp_fn = jax.vjp(
            lambda pp, *xx: model.tp_losstail_loss(pp, xx, tgt, aux_in, cfg,
                                                   stage, chunk, blocks,
                                                   post, first),
            p, *xs,
        )
        res = vjp_fn(jnp.float32(1.0))
        dp_leaves = jax.tree_util.tree_leaves(res[0])
        if tokens_in:
            return (loss, *dp_leaves)
        return (loss, *res[1:], *dp_leaves)

    ex_in = _glue_example_ins(cfg, stage, chunk, first, post)
    tgt = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return fn, [*leaves, *ex_in, tgt, jnp.float32(0.0)], names


# ---------------------------------------------------------------------------
# TP x EP rank artifacts (§3.3.2-3.3.4)
# ---------------------------------------------------------------------------


def make_moe_rank(cfg: ModelConfig, rank: int, tp: int):
    """One rank's partial MoE layer: (x, wg, w1, b1, w2, b2) -> (y_partial, aux)."""
    assert cfg.experts % tp == 0
    N = cfg.experts // tp
    t, h, f, E = cfg.tokens, cfg.hidden, cfg.ffn, cfg.experts

    def fn(x, wg, w1, b1, w2, b2):
        return model.moe_rank_partial(x, wg, w1, b1, w2, b2, rank, tp, cfg)

    ex = [
        jnp.zeros((t, h), jnp.float32),
        jnp.zeros((h, E), jnp.float32),
        jnp.zeros((N, h, f), jnp.float32),
        jnp.zeros((N, f), jnp.float32),
        jnp.zeros((N, f, h), jnp.float32),
        jnp.zeros((N, h), jnp.float32),
    ]
    return fn, ex


def make_ffn_mono(cfg: ModelConfig):
    """One big dense FFN over all t tokens — the monolithic side of the
    §3.3.2 serialization experiment."""
    from .kernels import dense_ffn

    t, h, f = cfg.tokens, cfg.hidden, cfg.ffn

    def fn(x, w1, b1, w2, b2):
        return (dense_ffn.dense_ffn(x, w1, b1, w2, b2,
                                    block_t=min(cfg.block_t, t)),)

    ex = [
        jnp.zeros((t, h), jnp.float32),
        jnp.zeros((h, f), jnp.float32),
        jnp.zeros((f,), jnp.float32),
        jnp.zeros((f, h), jnp.float32),
        jnp.zeros((h,), jnp.float32),
    ]
    return fn, ex


def make_ffn_grouped_eq(cfg: ModelConfig):
    """E small expert FFNs over t/E tokens each — same total FLOPs as
    `ffn_mono`; the grouped (serialized-experts) side of §3.3.2."""
    from .kernels import moe_ffn

    E, h, f = cfg.experts, cfg.hidden, cfg.ffn
    c = max(1, cfg.tokens // E)

    def fn(xd, w1, b1, w2, b2):
        return (moe_ffn.moe_ffn(xd, w1, b1, w2, b2,
                                block_c=min(cfg.block_c, c)),)

    ex = [
        jnp.zeros((E, c, h), jnp.float32),
        jnp.zeros((E, h, f), jnp.float32),
        jnp.zeros((E, f), jnp.float32),
        jnp.zeros((E, f, h), jnp.float32),
        jnp.zeros((E, h), jnp.float32),
    ]
    return fn, ex


def make_moe_single(cfg: ModelConfig):
    """Monolithic MoE layer: the reference the rank partials must sum to."""
    t, h, f, E = cfg.tokens, cfg.hidden, cfg.ffn, cfg.experts

    def fn(x, wg, w1, b1, w2, b2):
        return model.moe_layer_single(x, wg, w1, b1, w2, b2, cfg)

    ex = [
        jnp.zeros((t, h), jnp.float32),
        jnp.zeros((h, E), jnp.float32),
        jnp.zeros((E, h, f), jnp.float32),
        jnp.zeros((E, f), jnp.float32),
        jnp.zeros((E, f, h), jnp.float32),
        jnp.zeros((E, h), jnp.float32),
    ]
    return fn, ex
