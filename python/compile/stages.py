"""Flat-signature stage functions for AOT lowering.

HLO artifacts are shape-monomorphic and take flat argument lists, so this
module adapts the pytree-based model functions of `model.py` into functions
over (param_0, ..., param_k, x, ...) suitable for `jax.jit(...).lower()`.
Parameter order is the deterministic pytree flattening order (sorted dict
keys), recorded in the manifest so the Rust runtime can address tensors by
name.

Backward functions are *recompute-based*: `stage_bwd(params, x, dy, daux)`
re-runs the stage forward inside `jax.vjp` and returns (dx, dparams). This
keeps every artifact a pure function with flat array ins/outs — no residual
pytrees cross the Rust boundary — at the cost of one extra forward per
backward, exactly like Megatron's full activation recomputation (Chen et
al. 2016, cited by the paper §2).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import model
from .model import ModelConfig


def flatten_params(params: dict[str, Any]):
    """Deterministic (names, leaves, treedef) for a stage's param dict."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    names = [
        ".".join(str(k.key) for k in path) for path, _ in paths
    ]
    return names, leaves, treedef


def unflatten_params(treedef, leaves):
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Per-stage artifact factories. Each returns (fn, example_args) where fn has
# a flat signature ready for jax.jit(fn).lower(*example_args).
# ---------------------------------------------------------------------------


def _example_x(cfg: ModelConfig, stage: int):
    if stage == 0:
        return jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return jnp.zeros((cfg.micro_batch, cfg.seq, cfg.hidden), jnp.float32)


def make_stage_fwd(cfg: ModelConfig, stage: int, params: dict[str, Any]):
    """stage_fwd: (params..., x) -> (act, aux)."""
    names, leaves, treedef = flatten_params(params)

    def fn(*args):
        p = unflatten_params(treedef, list(args[:-1]))
        return model.stage_fwd(p, args[-1], cfg, stage)

    return fn, [*leaves, _example_x(cfg, stage)], names


def make_stage_bwd(cfg: ModelConfig, stage: int, params: dict[str, Any]):
    """stage_bwd: (params..., x, dy, daux) -> (dx?, dparams...).

    dx is emitted only for stage > 0 (stage 0's input is int tokens).
    """
    names, leaves, treedef = flatten_params(params)

    def fn(*args):
        p_leaves, x, dy, daux = list(args[:-3]), args[-3], args[-2], args[-1]
        p = unflatten_params(treedef, p_leaves)
        _, vjp_fn = jax.vjp(
            lambda pp, xx: model.stage_fwd(pp, xx, cfg, stage), p, x
        )
        dp, dx = vjp_fn((dy, daux))
        dp_leaves = jax.tree_util.tree_leaves(dp)
        if stage == 0:
            return tuple(dp_leaves)
        return (dx, *dp_leaves)

    dy = jnp.zeros((cfg.micro_batch, cfg.seq, cfg.hidden), jnp.float32)
    daux = jnp.float32(0.0)
    return fn, [*leaves, _example_x(cfg, stage), dy, daux], names


def make_last_stage_lossgrad(cfg: ModelConfig, params: dict[str, Any]):
    """lossgrad: (params..., x, targets, aux_in) -> (loss, dx, dparams...).

    The cotangent wrt aux_in is the constant cfg.aux_coef; the L3 trainer
    passes it straight to earlier stages' `daux`, so it is not re-emitted.
    """
    names, leaves, treedef = flatten_params(params)
    stage = cfg.stages - 1

    def fn(*args):
        p_leaves, x, tgt, aux_in = list(args[:-3]), args[-3], args[-2], args[-1]
        p = unflatten_params(treedef, p_leaves)
        loss, vjp_fn = jax.vjp(
            lambda pp, xx: model.last_stage_loss(pp, xx, tgt, aux_in, cfg), p, x
        )
        dp, dx = vjp_fn(jnp.float32(1.0))
        return (loss, dx, *jax.tree_util.tree_leaves(dp))

    x = _example_x(cfg, stage)
    tgt = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return fn, [*leaves, x, tgt, jnp.float32(0.0)], names


def make_last_stage_loss(cfg: ModelConfig, params: dict[str, Any]):
    """Eval-only loss: (params..., x, targets, aux_in) -> (loss,)."""
    names, leaves, treedef = flatten_params(params)
    stage = cfg.stages - 1

    def fn(*args):
        p_leaves, x, tgt, aux_in = list(args[:-3]), args[-3], args[-2], args[-1]
        p = unflatten_params(treedef, p_leaves)
        return (model.last_stage_loss(p, x, tgt, aux_in, cfg),)

    x = _example_x(cfg, stage)
    tgt = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return fn, [*leaves, x, tgt, jnp.float32(0.0)], names


def make_full_lossgrad(cfg: ModelConfig, all_params: list[dict[str, Any]]):
    """Whole-model single-shot (loss, grads...) — the §3.3.6 functional-
    equivalence reference the pipelined trainer is verified against."""
    flat = [flatten_params(p) for p in all_params]
    counts = [len(f[1]) for f in flat]

    def fn(*args):
        off, ps = 0, []
        for (names, _, treedef), n in zip(flat, counts):
            ps.append(unflatten_params(treedef, list(args[off:off + n])))
            off += n
        tokens, targets = args[-2], args[-1]
        loss, grads = jax.value_and_grad(
            lambda pp: model.full_loss(pp, tokens, targets, cfg)
        )(ps)
        return (loss, *jax.tree_util.tree_leaves(grads))

    leaves = [leaf for f in flat for leaf in f[1]]
    names = [f"stage{s}.{n}" for s, f in enumerate(flat) for n in f[0]]
    tokens = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    targets = jnp.zeros((cfg.micro_batch, cfg.seq), jnp.int32)
    return fn, [*leaves, tokens, targets], names


# ---------------------------------------------------------------------------
# TP x EP rank artifacts (§3.3.2-3.3.4)
# ---------------------------------------------------------------------------


def make_moe_rank(cfg: ModelConfig, rank: int, tp: int):
    """One rank's partial MoE layer: (x, wg, w1, b1, w2, b2) -> (y_partial, aux)."""
    assert cfg.experts % tp == 0
    N = cfg.experts // tp
    t, h, f, E = cfg.tokens, cfg.hidden, cfg.ffn, cfg.experts

    def fn(x, wg, w1, b1, w2, b2):
        return model.moe_rank_partial(x, wg, w1, b1, w2, b2, rank, tp, cfg)

    ex = [
        jnp.zeros((t, h), jnp.float32),
        jnp.zeros((h, E), jnp.float32),
        jnp.zeros((N, h, f), jnp.float32),
        jnp.zeros((N, f), jnp.float32),
        jnp.zeros((N, f, h), jnp.float32),
        jnp.zeros((N, h), jnp.float32),
    ]
    return fn, ex


def make_ffn_mono(cfg: ModelConfig):
    """One big dense FFN over all t tokens — the monolithic side of the
    §3.3.2 serialization experiment."""
    from .kernels import dense_ffn

    t, h, f = cfg.tokens, cfg.hidden, cfg.ffn

    def fn(x, w1, b1, w2, b2):
        return (dense_ffn.dense_ffn(x, w1, b1, w2, b2,
                                    block_t=min(cfg.block_t, t)),)

    ex = [
        jnp.zeros((t, h), jnp.float32),
        jnp.zeros((h, f), jnp.float32),
        jnp.zeros((f,), jnp.float32),
        jnp.zeros((f, h), jnp.float32),
        jnp.zeros((h,), jnp.float32),
    ]
    return fn, ex


def make_ffn_grouped_eq(cfg: ModelConfig):
    """E small expert FFNs over t/E tokens each — same total FLOPs as
    `ffn_mono`; the grouped (serialized-experts) side of §3.3.2."""
    from .kernels import moe_ffn

    E, h, f = cfg.experts, cfg.hidden, cfg.ffn
    c = max(1, cfg.tokens // E)

    def fn(xd, w1, b1, w2, b2):
        return (moe_ffn.moe_ffn(xd, w1, b1, w2, b2,
                                block_c=min(cfg.block_c, c)),)

    ex = [
        jnp.zeros((E, c, h), jnp.float32),
        jnp.zeros((E, h, f), jnp.float32),
        jnp.zeros((E, f), jnp.float32),
        jnp.zeros((E, f, h), jnp.float32),
        jnp.zeros((E, h), jnp.float32),
    ]
    return fn, ex


def make_moe_single(cfg: ModelConfig):
    """Monolithic MoE layer: the reference the rank partials must sum to."""
    t, h, f, E = cfg.tokens, cfg.hidden, cfg.ffn, cfg.experts

    def fn(x, wg, w1, b1, w2, b2):
        return model.moe_layer_single(x, wg, w1, b1, w2, b2, cfg)

    ex = [
        jnp.zeros((t, h), jnp.float32),
        jnp.zeros((h, E), jnp.float32),
        jnp.zeros((E, h, f), jnp.float32),
        jnp.zeros((E, f), jnp.float32),
        jnp.zeros((E, f, h), jnp.float32),
        jnp.zeros((E, h), jnp.float32),
    ]
    return fn, ex
