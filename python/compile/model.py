"""L2 — the PPMoE transformer in JAX (build-time only, never on request path).

Decoder-only transformer in the paper's configuration family (§4.1): GPT-3
style blocks, with every other FFN replaced by an MoE layer of E experts and
top-k gating (top-1 by default, matching the paper; `top_k` in ModelConfig
generalizes the schedule). The MoE layer calls the L1 Pallas kernels (router + grouped
expert FFN); dispatch is capacity-based with C = tokens, which is
functionally PPMoE's uncapped index-slice dispatch (§4.1: "PPMoE abandoned
the capacity limit").

Everything here is pure-functional over explicit parameter pytrees so that
`aot.py` can lower per-pipeline-stage fwd/bwd functions to HLO text for the
Rust runtime. Parameters are fp32 (the paper uses fp16 + fp32 gating on
V100; on CPU-PJRT we keep fp32 throughout and note the substitution in
EXPERIMENTS.md §Substitutions).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import dense_ffn, gating, moe_ffn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (paper §4.1 family, scaled down)."""

    vocab: int = 512
    hidden: int = 128
    ffn: int = 512  # 4*hidden
    layers: int = 4
    heads: int = 4
    experts: int = 8
    moe_every: int = 2  # every other FFN is MoE, like the paper
    seq: int = 64
    micro_batch: int = 4
    stages: int = 2  # pipeline stages
    # Interleaved virtual-stage 1F1B (Megatron-style): each physical stage
    # holds this many NON-contiguous model chunks. Virtual stage
    # V = chunk*stages + stage owns layers [V*n, (V+1)*n) with
    # n = layers/(stages*virtual_stages); chunk c of the last stage feeds
    # chunk c+1 of stage 0 (the wrap-around p2p edge). 1 = plain pipeline.
    virtual_stages: int = 1
    aux_coef: float = 0.01
    # Expert capacity factor (§Perf L2). capacity = cf·k·tokens/E, so the
    # grouped kernel computes cf·k× one dense FFN instead of E×. cf = 0
    # means "uncapped" (capacity = tokens, zero drops — the paper's §4.1
    # setting, at E× the FLOPs in static-shape HLO). With the aux balance
    # loss active cf = 2 drops <1% of tokens in practice; dropped tokens
    # pass through the residual connection, standard GShard/Switch
    # behaviour.
    capacity_factor: float = 2.0
    # Gating schedule: each token is dispatched to its top_k experts, gate
    # weights renormalized over the winners (GShard style) at k > 1 and the
    # raw top-1 probability at k = 1 — so the default reproduces the
    # paper's top-1 artifacts bitwise. See kernels/gating.make_dispatch_topk.
    top_k: int = 1
    # pallas block sizes (perf knobs, see EXPERIMENTS.md §Perf)
    block_c: int = 64
    block_t: int = 128

    @property
    def tokens(self) -> int:
        return self.micro_batch * self.seq

    @property
    def capacity(self) -> int:
        if self.capacity_factor <= 0:
            # uncapped: every token fits even if all pick one expert
            return self.tokens
        # k slots per token on average: capacity scales with the gating
        # fan-out (reduces to the historic cf·tokens/E at top_k = 1)
        cap = int(self.capacity_factor * self.top_k * self.tokens / self.experts)
        cap = max(8, (cap + 7) // 8 * 8)  # pad to 8 for tiling
        return min(cap, self.tokens)

    @property
    def moe_block_c(self) -> int:
        """Pallas capacity-tile for the grouped expert FFN: the largest
        divisor of `capacity` that is <= block_c. The historic
        min(block_c, capacity) clamp only covers capacity <= block_c; a
        top-k capacity (cf·k·tokens/E) can exceed block_c without being a
        multiple of it (e.g. 48 vs 32), which the kernel grid rejects."""
        cap = self.capacity
        b = min(self.block_c, cap)
        while cap % b:
            b -= 1
        return b

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def is_moe_layer(self, i: int) -> bool:
        # layers 1, 3, 5, ... are MoE ("every other FFN")
        return self.moe_every > 0 and (i % self.moe_every == self.moe_every - 1)

    @property
    def num_virtual(self) -> int:
        """Total virtual stages in the ring: stages * virtual_stages."""
        return self.stages * self.virtual_stages

    def validate(self) -> None:
        assert self.hidden % self.heads == 0
        assert self.layers % self.num_virtual == 0, (
            f"layers ({self.layers}) must split evenly over "
            f"{self.stages} stages x {self.virtual_stages} chunks"
        )
        if not 1 <= self.top_k <= self.experts:
            raise ValueError(
                f"top_k ({self.top_k}) must be between 1 and the expert "
                f"count ({self.experts}) — a token cannot be routed to "
                "more experts than exist"
            )
        if 0 < self.capacity_factor < 1.0 / self.experts:
            raise ValueError(
                f"capacity_factor ({self.capacity_factor}) is below "
                f"1/experts ({1.0 / self.experts:.4f}): total expert slots "
                "would round toward zero and silently drop nearly every "
                "token — raise it, or use 0 for uncapped dispatch"
            )


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def init_block(key: jax.Array, cfg: ModelConfig, layer_idx: int) -> dict[str, Any]:
    """One transformer block: pre-LN attention + pre-LN (MoE-)FFN."""
    h, f, E = cfg.hidden, cfg.ffn, cfg.experts
    ks = jax.random.split(key, 10)
    s_attn = 0.02
    s_proj = 0.02 / jnp.sqrt(2.0 * cfg.layers)
    p: dict[str, Any] = {
        "ln1_g": jnp.ones((h,), jnp.float32),
        "ln1_b": jnp.zeros((h,), jnp.float32),
        "wqkv": jax.random.normal(ks[0], (h, 3 * h), jnp.float32) * s_attn,
        "bqkv": jnp.zeros((3 * h,), jnp.float32),
        "wo": jax.random.normal(ks[1], (h, h), jnp.float32) * s_proj,
        "bo": jnp.zeros((h,), jnp.float32),
        "ln2_g": jnp.ones((h,), jnp.float32),
        "ln2_b": jnp.zeros((h,), jnp.float32),
    }
    if cfg.is_moe_layer(layer_idx):
        p.update(
            wg=jax.random.normal(ks[2], (h, E), jnp.float32) * s_attn,
            w1=jax.random.normal(ks[3], (E, h, f), jnp.float32) * s_attn,
            b1=jnp.zeros((E, f), jnp.float32),
            w2=jax.random.normal(ks[4], (E, f, h), jnp.float32) * s_proj,
            b2=jnp.zeros((E, h), jnp.float32),
        )
    else:
        p.update(
            w1=jax.random.normal(ks[3], (h, f), jnp.float32) * s_attn,
            b1=jnp.zeros((f,), jnp.float32),
            w2=jax.random.normal(ks[4], (f, h), jnp.float32) * s_proj,
            b2=jnp.zeros((h,), jnp.float32),
        )
    return p


def init_chunk(key: jax.Array, cfg: ModelConfig, stage: int, chunk: int) -> dict[str, Any]:
    """Parameters owned by one virtual chunk of a pipeline stage.

    Virtual stage 0 (= stage 0, chunk 0) additionally owns the embeddings;
    the last virtual stage (= last stage, last chunk) owns the final
    LayerNorm and the (untied) output projection. Block keys are local to
    the chunk; the global layer index is recovered from the virtual-stage
    arithmetic.
    """
    n = cfg.layers // cfg.num_virtual
    v_idx = chunk * cfg.stages + stage
    ks = jax.random.split(key, n + 2)
    p: dict[str, Any] = {
        f"block{j:02d}": init_block(ks[j], cfg, v_idx * n + j) for j in range(n)
    }
    if v_idx == 0:
        p["tok_emb"] = jax.random.normal(ks[n], (cfg.vocab, cfg.hidden)) * 0.02
        p["pos_emb"] = jax.random.normal(ks[n + 1], (cfg.seq, cfg.hidden)) * 0.02
    if v_idx == cfg.num_virtual - 1:
        p["lnf_g"] = jnp.ones((cfg.hidden,), jnp.float32)
        p["lnf_b"] = jnp.zeros((cfg.hidden,), jnp.float32)
        p["w_out"] = jax.random.normal(ks[n], (cfg.hidden, cfg.vocab)) * 0.02
    return p


def init_stage(key: jax.Array, cfg: ModelConfig, stage: int) -> dict[str, Any]:
    """Parameters owned by one pipeline stage (plain pipelines only —
    chunked configs init per (stage, chunk) via `init_chunk`)."""
    assert cfg.virtual_stages == 1
    return init_chunk(key, cfg, stage, 0)


def init_all(key: jax.Array, cfg: ModelConfig) -> list[dict[str, Any]]:
    ks = jax.random.split(key, cfg.stages)
    return [init_stage(ks[s], cfg, s) for s in range(cfg.stages)]


def init_all_chunks(key: jax.Array, cfg: ModelConfig) -> list[list[dict[str, Any]]]:
    """Per-(stage, chunk) parameters, indexed [stage][chunk].

    Keys split per virtual stage in ring order, so `virtual_stages == 1`
    reproduces `init_all` bitwise (jax.random.split(key, n) is a prefix of
    the same-key split at larger n only when n matches — hence the split is
    over exactly `num_virtual` keys, which equals `stages` at v = 1).
    """
    ks = jax.random.split(key, cfg.num_virtual)
    return [
        [init_chunk(ks[c * cfg.stages + s], cfg, s, c)
         for c in range(cfg.virtual_stages)]
        for s in range(cfg.stages)
    ]


# ---------------------------------------------------------------------------
# Forward pieces
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def attention(p: dict[str, Any], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Causal multi-head self-attention. x: (B, S, h)."""
    B, S, h = x.shape
    qkv = jnp.dot(x, p["wqkv"]) + p["bqkv"]  # (B, S, 3h)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # (B, S, h) -> (B, nh, S, hd)
        return t.reshape(B, S, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    scores = jnp.einsum("bnqd,bnkd->bnqk", q, k) / jnp.sqrt(float(cfg.head_dim))
    mask = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(mask, scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnqk,bnkd->bnqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, h)
    return jnp.dot(out, p["wo"]) + p["bo"]


def make_dispatch_cfg(probs, top1, cfg: ModelConfig):
    """Dispatch/combine tensors under cfg's gating schedule.

    top_k == 1 keeps the historic `make_dispatch` call so existing top-1
    artifacts re-lower bitwise unchanged; k > 1 routes through the general
    k-slot builder (renormalized gate weights applied in the combine,
    BEFORE the trainer's single inner-node all-reduce of rank partials).
    """
    if cfg.top_k == 1:
        return gating.make_dispatch(probs, top1, cfg.experts, cfg.capacity)
    return gating.make_dispatch_topk(probs, cfg.experts, cfg.capacity,
                                     cfg.top_k)


def moe_ffn_layer(p: dict[str, Any], x: jax.Array, cfg: ModelConfig):
    """PPMoE MoE layer (single-rank view): route -> index-dispatch -> grouped
    expert FFN (L1 kernel) -> combine. x: (B, S, h) -> ((B, S, h), aux)."""
    B, S, h = x.shape
    xf = x.reshape(B * S, h)
    probs, top1 = gating.router(xf, p["wg"], block_t=min(cfg.block_t, B * S))
    dispatch, combine, aux = make_dispatch_cfg(probs, top1, cfg)
    xd = jnp.einsum("tec,th->ech", dispatch, xf)
    yd = moe_ffn.moe_ffn(
        xd, p["w1"], p["b1"], p["w2"], p["b2"],
        block_c=cfg.moe_block_c,
    )
    y = jnp.einsum("tec,ech->th", combine, yd)
    return y.reshape(B, S, h), aux


def dense_ffn_layer(p: dict[str, Any], x: jax.Array, cfg: ModelConfig):
    B, S, h = x.shape
    xf = x.reshape(B * S, h)
    y = dense_ffn.dense_ffn(
        xf, p["w1"], p["b1"], p["w2"], p["b2"],
        block_t=min(cfg.block_t, B * S),
    )
    return y.reshape(B, S, h)


def block_fwd(p: dict[str, Any], x: jax.Array, cfg: ModelConfig, layer_idx: int):
    """One transformer block. Returns (y, aux_loss)."""
    a = attention(p, layer_norm(x, p["ln1_g"], p["ln1_b"]), cfg)
    x = x + a
    hgt = layer_norm(x, p["ln2_g"], p["ln2_b"])
    if cfg.is_moe_layer(layer_idx):
        y, aux = moe_ffn_layer(p, hgt, cfg)
    else:
        y, aux = dense_ffn_layer(p, hgt, cfg), jnp.float32(0.0)
    return x + y, aux


# ---------------------------------------------------------------------------
# Stage functions (what gets lowered per pipeline stage)
# ---------------------------------------------------------------------------


def chunk_fwd(params: dict[str, Any], x: jax.Array, cfg: ModelConfig,
              stage: int, chunk: int):
    """Forward through one virtual chunk of a pipeline stage.

    Virtual stage 0 takes int32 tokens (B, S); every other chunk takes
    activations (B, S, h) — including chunk c > 0 of stage 0, which
    receives the wrap-around activations of chunk c−1 leaving the last
    stage. Returns (activations, aux_loss_sum) — aux is threaded as a
    scalar through the whole virtual ring so the loss head adds it exactly
    once.
    """
    n = cfg.layers // cfg.num_virtual
    v_idx = chunk * cfg.stages + stage
    aux_total = jnp.float32(0.0)
    if v_idx == 0:
        h = params["tok_emb"][x] + params["pos_emb"][None, :, :]
    else:
        h = x
    for j in range(n):
        h, aux = block_fwd(params[f"block{j:02d}"], h, cfg, v_idx * n + j)
        aux_total = aux_total + aux
    return h, aux_total


def stage_fwd(params: dict[str, Any], x: jax.Array, cfg: ModelConfig, stage: int):
    """Forward through one pipeline stage — the single-chunk view
    (`chunk_fwd` at chunk 0; identical at virtual_stages == 1)."""
    return chunk_fwd(params, x, cfg, stage, 0)


def loss_head(params: dict[str, Any], h: jax.Array, targets: jax.Array,
              aux_in: jax.Array, cfg: ModelConfig):
    """Final LN + projection + softmax cross-entropy + aux balance loss."""
    h = layer_norm(h, params["lnf_g"], params["lnf_b"])
    logits = jnp.dot(h, params["w_out"])  # (B, S, V)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + cfg.aux_coef * aux_in


def last_stage_loss(params, x, targets, aux_in, cfg: ModelConfig):
    """Forward through the last virtual chunk + loss. aux_in: accumulated
    aux scalar from every earlier chunk in the ring (threaded through the
    pipeline — wrap-around edges included — by the L3 trainer)."""
    h, aux = chunk_fwd(params, x, cfg, cfg.stages - 1, cfg.virtual_stages - 1)
    return loss_head(params, h, targets, aux + aux_in, cfg)


def full_loss_chunks(chunk_params: list[list[dict[str, Any]]], tokens, targets,
                     cfg: ModelConfig):
    """Single-shot whole-model loss over [stage][chunk] parameters: chain
    the virtual ring in order (stage-inner, chunk-outer) and close with the
    loss head — the §3.3.6 functional-equivalence reference for the
    interleaved trainer."""
    h, aux = tokens, jnp.float32(0.0)
    for v_idx in range(cfg.num_virtual - 1):
        s, c = v_idx % cfg.stages, v_idx // cfg.stages
        h, a = chunk_fwd(chunk_params[s][c], h, cfg, s, c)
        aux = aux + a
    return last_stage_loss(chunk_params[-1][-1], h, targets, aux, cfg)


def full_loss(all_params: list[dict[str, Any]], tokens, targets, cfg: ModelConfig):
    """Single-shot whole-model loss (the functional-equivalence reference of
    §3.3.6: PPMoE's grad accumulation must match this up to fp tolerance)."""
    return full_loss_chunks([[p] for p in all_params], tokens, targets, cfg)


# ---------------------------------------------------------------------------
# Tensor-parallel x expert-parallel rank view (§3.3.2-3.3.4)
# ---------------------------------------------------------------------------


def moe_rank_partial(x, wg, w1_loc, b1_loc, w2_loc, b2_loc,
                     rank: int, tp: int, cfg: ModelConfig):
    """One TP rank's share of a PPMoE MoE layer.

    Every rank holds the *full* gating weights and the identical input x, so
    the dispatch order is identical on all ranks (§3.3.3). Each rank then
    index-slices only the tokens routed to its N = E/T local experts,
    computes them, and emits a partial output; the Rust L3 all-reduces (sums)
    partials across ranks — the inner-node all-reduce that replaces the two
    all-to-alls of DPMoE.

    x: (t, h). Local expert range: [rank*N, (rank+1)*N).
    Returns (partial_y (t, h), aux).
    """
    E = cfg.experts
    N = E // tp
    probs, top1 = gating.router(x, wg, block_t=min(cfg.block_t, x.shape[0]))
    dispatch, combine, aux = make_dispatch_cfg(probs, top1, cfg)
    # slice to this rank's experts only — the "tensor index slicing" of the
    # title; a static slice because rank/tp are compile-time constants here.
    lo = rank * N
    d_loc = dispatch[:, lo:lo + N, :]
    c_loc = combine[:, lo:lo + N, :]
    xd = jnp.einsum("tec,th->ech", d_loc, x)
    yd = moe_ffn.moe_ffn(
        xd, w1_loc, b1_loc, w2_loc, b2_loc,
        block_c=cfg.moe_block_c,
    )
    y = jnp.einsum("tec,ech->th", c_loc, yd)
    return y, aux


def tp_glue_fwd(params, xs, cfg: ModelConfig, stage: int, chunk: int,
                blocks: tuple[int, int], pre_moe: int | None, post_moe: bool,
                first: bool):
    """One replicated "glue" segment of a tp-pipeline chunk.

    The tp export cuts every chunk at its MoE layers: glue segments hold the
    replicated compute (dense blocks, attention, LayerNorms) and run
    identically on every tp rank, while the cut-out MoE layers run as
    per-rank ``tp_moe_fwd`` partials combined by the trainer's inner-node
    all-reduce. A glue segment:

    * takes the chunk input ``(x,)`` when it opens the chunk (``first``), or
      the pair ``(x_res, y_combined)`` when it follows a combine
      (``post_moe`` — the residual add lives here, AFTER the all-reduce, so
      the sum decomposition of the partials stays exact);
    * runs the dense blocks in ``blocks`` (aux is structurally zero there);
    * and, when ``pre_moe`` names the next MoE block, stops mid-block after
      that block's attention + pre-MoE LayerNorm, returning ``(x_res, hgt)``
      — ``hgt`` is the tensor every rank's MoE partial index-slices.
    """
    n = cfg.layers // cfg.num_virtual
    v_idx = chunk * cfg.stages + stage
    if post_moe:
        h = xs[0] + xs[1]
    elif first and v_idx == 0:
        h = params["tok_emb"][xs[0]] + params["pos_emb"][None, :, :]
    else:
        h = xs[0]
    for j in range(*blocks):
        h, _aux = block_fwd(params[f"block{j:02d}"], h, cfg, v_idx * n + j)
    if pre_moe is not None:
        bp = params[f"block{pre_moe:02d}"]
        x2 = h + attention(bp, layer_norm(h, bp["ln1_g"], bp["ln1_b"]), cfg)
        hgt = layer_norm(x2, bp["ln2_g"], bp["ln2_b"])
        return (x2, hgt)
    return (h,)


def tp_moe_fwd(params, hgt, cfg: ModelConfig, rank: int, tp: int):
    """One rank's MoE segment of a tp-pipeline chunk: the ``moe_rank``
    scheme applied to the stage-local activation ``hgt`` (B, S, h). Returns
    this rank's partial output (summed across ranks by the trainer's
    all-reduce) and the aux balance loss (computed identically on every
    rank from the full gating weights — only the trainer's rank 0 threads
    its value, and only rank 0 receives the aux cotangent in the backward,
    so the sum of the rank gradients is exactly the monolithic gradient)."""
    B, S, h = hgt.shape
    y, aux = moe_rank_partial(
        hgt.reshape(B * S, h), params["wg"], params["w1"], params["b1"],
        params["w2"], params["b2"], rank, tp, cfg)
    return y.reshape(B, S, h), aux


def tp_losstail_loss(params, xs, targets, aux_in, cfg: ModelConfig,
                     stage: int, chunk: int, blocks: tuple[int, int],
                     post_moe: bool, first: bool):
    """The loss chunk's final replicated segment: glue-style entry (the
    residual add when it follows an MoE combine), the trailing dense
    blocks, then the loss head. ``aux_in`` carries the ring-threaded aux
    scalar PLUS this chunk's own MoE segments' aux (added host-side by the
    trainer — unlike the fused monolithic ``lossgrad``, the tp loss tail
    computes no gating of its own)."""
    n = cfg.layers // cfg.num_virtual
    v_idx = chunk * cfg.stages + stage
    if post_moe:
        h = xs[0] + xs[1]
    elif first and v_idx == 0:
        h = params["tok_emb"][xs[0]] + params["pos_emb"][None, :, :]
    else:
        h = xs[0]
    for j in range(*blocks):
        h, _aux = block_fwd(params[f"block{j:02d}"], h, cfg, v_idx * n + j)
    return loss_head(params, h, targets, aux_in, cfg)


def moe_layer_single(x, wg, w1, b1, w2, b2, cfg: ModelConfig):
    """Monolithic single-rank MoE layer — the numerics reference the TP×EP
    rank decomposition must sum to (verified in rust integration tests)."""
    probs, top1 = gating.router(x, wg, block_t=min(cfg.block_t, x.shape[0]))
    dispatch, combine, aux = make_dispatch_cfg(probs, top1, cfg)
    xd = jnp.einsum("tec,th->ech", dispatch, x)
    yd = moe_ffn.moe_ffn(xd, w1, b1, w2, b2,
                         block_c=cfg.moe_block_c)
    return jnp.einsum("tec,ech->th", combine, yd), aux
