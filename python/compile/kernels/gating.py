"""Router (gating) Pallas kernel + dispatch/combine construction (L1).

The gating module of §3.3.3: a linear map, a softmax score function, and a
top-k schedule. The score computation (logits -> softmax -> top-1) is a
Pallas kernel tiled over tokens; the dispatch/combine tensor construction is
a cumsum-based one-hot assignment in plain jnp (it is a prefix-scan, not a
GEMM, so it does not benefit from the MXU — see EXPERIMENTS.md
§Serialization).

PPMoE's key structural property is encoded here: given identical inputs and
identical gating weights, every tensor-parallel rank computes the *identical*
dispatch order, so dispatch is a local index-slice and no all-to-all is
needed. Determinism of this function is what the Rust L3 relies on, and is
property-tested both in pytest and (for the rust re-implementation) proptest.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(x_ref, wg_ref, probs_ref, top1_ref):
    """One token tile: logits -> stable softmax -> top-1 index."""
    logits = jnp.dot(x_ref[...], wg_ref[...], preferred_element_type=jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    probs_ref[...] = probs
    top1_ref[...] = jnp.argmax(probs, axis=-1).astype(jnp.int32)


def _router_call(block_t, x, wg):
    t, h = x.shape
    E = wg.shape[1]
    assert t % block_t == 0, f"tokens {t} not divisible by block_t {block_t}"
    return pl.pallas_call(
        _router_kernel,
        grid=(t // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, h), lambda i: (i, 0)),
            pl.BlockSpec((h, E), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, E), lambda i: (i, 0)),
            pl.BlockSpec((block_t,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, E), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.int32),
        ],
        interpret=True,
    )(x, wg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _router_vjp(block_t, x, wg):
    return _router_call(block_t, x, wg)


def _router_vjp_fwd(block_t, x, wg):
    probs, top1 = _router_call(block_t, x, wg)
    return (probs, top1), (x, wg, probs)


def _router_vjp_bwd(block_t, res, cts):
    """Softmax + matmul backward (jnp; a prefix of elementwise ops, not MXU
    work, so it stays outside pallas). top1 is integer-valued: zero grad."""
    x, wg, probs = res
    dprobs, _dtop1 = cts
    # d softmax: dl = p * (dp - sum(dp * p))
    inner = jnp.sum(dprobs * probs, axis=-1, keepdims=True)
    dlogits = probs * (dprobs - inner)
    dx = jnp.dot(dlogits, wg.T, preferred_element_type=jnp.float32)
    dwg = jnp.dot(x.T, dlogits, preferred_element_type=jnp.float32)
    return dx, dwg


_router_vjp.defvjp(_router_vjp_fwd, _router_vjp_bwd)


def router(x, wg, *, block_t: int | None = None):
    """Gating scores: (t, h) x (h, E) -> (probs (t, E), top1 (t,) int32).

    Differentiable in x and wg (softmax-matmul backward); top1 carries no
    gradient. The gating module stays fp32 like the paper (§4.1).
    """
    if block_t is None:
        block_t = min(x.shape[0], 128)
    return _router_vjp(block_t, x, wg)


def make_dispatch(probs, top1, num_experts: int, capacity: int):
    """Build dispatch/combine tensors + aux loss from router output.

    Identical math to ref.make_dispatch_ref (kept separate so the oracle
    stays kernel-free). With capacity >= t this is PPMoE's uncapped
    index-slice dispatch: a bijection token -> (expert, slot).
    """
    onehot = jax.nn.one_hot(top1, num_experts, dtype=jnp.float32)
    pos = jnp.cumsum(onehot, axis=0) * onehot - onehot
    pos = jnp.sum(pos, axis=-1).astype(jnp.int32)
    keep = (pos < capacity).astype(jnp.float32)
    pos_onehot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)
    dispatch = onehot[:, :, None] * pos_onehot[:, None, :] * keep[:, None, None]
    gate = jnp.sum(probs * onehot, axis=-1)
    combine = dispatch * gate[:, None, None]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(onehot, axis=0)
    aux = num_experts * jnp.sum(me * ce)
    return dispatch, combine, aux


def make_dispatch_topk(probs, num_experts: int, capacity: int, k: int):
    """Top-k dispatch/combine: the general gating schedule (§3.3.3).

    Expert selection is k rounds of argmax-with-masking, which reproduces
    `jnp.top_k`'s first-occurrence tie semantics exactly (equal scores are
    taken in ascending expert order). Slot assignment is *level-major*:
    every token's first choice fills slabs first (scanning tokens in
    order), then every second choice continues with a per-expert base
    offset equal to the count of ALL first choices — dropped ones included
    — and so on; an assignment whose position reaches `capacity` is
    dropped (the token's OTHER choices survive independently).

    Gate weights: at k = 1 the raw top-1 softmax probability (bitwise
    `make_dispatch`, so existing top-1 artifacts are unchanged); at k > 1
    the selected probabilities renormalized over the k winners with
    `denom = max(sum, 1e-9)`, GShard style (bitwise `make_dispatch_top2`
    at k = 2). The aux balance loss always uses the top-1 assignment
    fractions, like both existing variants.

    Returns (dispatch, combine, aux) with the top-1 shapes: per (token,
    expert) at most ONE slot is set (the k winners are distinct), which is
    what keeps the per-rank index-slice decomposition exact at any k —
    every nonzero combine entry belongs to exactly one expert owner.
    """
    if not 1 <= k <= num_experts:
        raise ValueError(
            f"top_k ({k}) must be between 1 and num_experts ({num_experts})"
            " — a token cannot be routed to more experts than exist"
        )
    masked = probs
    ohs, gates = [], []
    for _ in range(k):
        top = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        oh = jax.nn.one_hot(top, num_experts, dtype=jnp.float32)
        ohs.append(oh)
        gates.append(jnp.sum(probs * oh, axis=-1))
        masked = masked * (1.0 - oh)
    if k > 1:
        total = gates[0]
        for g in gates[1:]:
            total = total + g
        denom = jnp.maximum(total, 1e-9)
        gates = [g / denom for g in gates]

    def slotted(oh, pos):
        keep = (pos < capacity).astype(jnp.float32)
        return oh[:, :, None] * jax.nn.one_hot(pos, capacity, dtype=jnp.float32)[
            :, None, :
        ] * keep[:, None, None]

    base = jnp.zeros((1, num_experts), jnp.float32)
    dispatch = None
    combine = None
    for oh, g in zip(ohs, gates):
        pos = jnp.cumsum(oh, axis=0) * oh - oh + base * oh
        pos = jnp.sum(pos, axis=-1).astype(jnp.int32)
        d = slotted(oh, pos)
        c = d * g[:, None, None]
        dispatch = d if dispatch is None else dispatch + d
        combine = c if combine is None else combine + c
        base = base + jnp.sum(oh, axis=0, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(ohs[0], axis=0)
    aux = num_experts * jnp.sum(me * ce)
    return dispatch, combine, aux


def make_dispatch_top2(probs, num_experts: int, capacity: int):
    """Top-2 variant (§3.3.3: 'compatible with existing gating schedules').

    Second expert's gate weight is renormalized against the first, GShard
    style. Returns (dispatch, combine, aux) with the same shapes as top-1.
    `make_dispatch_topk(..., k=2)` computes the identical tensors; this
    explicit form is kept as the readable two-level reference.
    """
    top1 = jnp.argmax(probs, axis=-1).astype(jnp.int32)
    probs_wo1 = probs * (1.0 - jax.nn.one_hot(top1, num_experts, dtype=jnp.float32))
    top2 = jnp.argmax(probs_wo1, axis=-1).astype(jnp.int32)

    oh1 = jax.nn.one_hot(top1, num_experts, dtype=jnp.float32)
    oh2 = jax.nn.one_hot(top2, num_experts, dtype=jnp.float32)
    # slot positions: first choices fill slabs first, then second choices
    pos1 = jnp.cumsum(oh1, axis=0) * oh1 - oh1
    pos1 = jnp.sum(pos1, axis=-1).astype(jnp.int32)
    base2 = jnp.sum(oh1, axis=0, keepdims=True)  # tokens already placed per e
    pos2 = jnp.cumsum(oh2, axis=0) * oh2 - oh2 + base2 * oh2
    pos2 = jnp.sum(pos2, axis=-1).astype(jnp.int32)

    g1 = jnp.sum(probs * oh1, axis=-1)
    g2 = jnp.sum(probs * oh2, axis=-1)
    denom = jnp.maximum(g1 + g2, 1e-9)
    g1, g2 = g1 / denom, g2 / denom

    def slotted(oh, pos):
        keep = (pos < capacity).astype(jnp.float32)
        return oh[:, :, None] * jax.nn.one_hot(pos, capacity, dtype=jnp.float32)[
            :, None, :
        ] * keep[:, None, None]

    d1, d2 = slotted(oh1, pos1), slotted(oh2, pos2)
    dispatch = d1 + d2
    combine = d1 * g1[:, None, None] + d2 * g2[:, None, None]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(oh1, axis=0)
    aux = num_experts * jnp.sum(me * ce)
    return dispatch, combine, aux
