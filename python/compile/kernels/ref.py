"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written with
plain jax.numpy ops only. pytest (python/tests/) asserts allclose between the
kernel (interpret=True) and these oracles across a hypothesis-driven sweep of
shapes and dtypes — this file is the correctness ground truth for L1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gelu(x: jax.Array) -> jax.Array:
    """tanh-approx GeLU, matching the kernel's in-VMEM activation."""
    return (
        0.5
        * x
        * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))
    )


def dense_ffn_ref(x, w1, b1, w2, b2):
    """Dense transformer FFN: GeLU(x @ w1 + b1) @ w2 + b2.

    x: (t, h); w1: (h, f); b1: (f,); w2: (f, h); b2: (h,).
    """
    hidden = gelu(jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1)
    return jnp.dot(hidden, w2, preferred_element_type=jnp.float32) + b2


def moe_ffn_ref(xd, w1, b1, w2, b2):
    """Grouped expert FFN over dispatched tokens.

    xd: (E, C, h) — capacity-dispatched token tiles, one slab per expert.
    w1: (E, h, f); b1: (E, f); w2: (E, f, h); b2: (E, h).
    Returns (E, C, h).
    """
    hidden = gelu(
        jnp.einsum("ech,ehf->ecf", xd, w1, preferred_element_type=jnp.float32)
        + b1[:, None, :]
    )
    return (
        jnp.einsum("ecf,efh->ech", hidden, w2, preferred_element_type=jnp.float32)
        + b2[:, None, :]
    )


def router_ref(x, wg):
    """Gating scores: softmax(x @ wg) and the top-1 expert per token.

    x: (t, h); wg: (h, E).  Returns (probs (t, E), top1 (t,) int32).
    """
    logits = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    return probs, jnp.argmax(probs, axis=-1).astype(jnp.int32)


def make_dispatch_ref(probs, top1, num_experts: int, capacity: int):
    """GShard-style dispatch/combine tensors with capacity C.

    With C >= t this is functionally PPMoE's uncapped index-slice dispatch:
    no token is ever dropped, every token lands in exactly one (e, c) slot.

    Returns:
      dispatch: (t, E, C) float — one-hot token->slot routing mask.
      combine:  (t, E, C) float — dispatch scaled by the token's gate prob.
      aux_loss: scalar — GShard load-balancing loss, E * sum(me * ce).
    """
    onehot = jax.nn.one_hot(top1, num_experts, dtype=jnp.float32)  # (t, E)
    # position of each token inside its expert's slab (0-indexed)
    pos = jnp.cumsum(onehot, axis=0) * onehot - onehot  # (t, E)
    pos = jnp.sum(pos, axis=-1).astype(jnp.int32)  # (t,)
    keep = (pos < capacity).astype(jnp.float32)
    pos_onehot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)  # (t, C)
    dispatch = onehot[:, :, None] * pos_onehot[:, None, :] * keep[:, None, None]
    gate = jnp.sum(probs * onehot, axis=-1)  # (t,) prob of the chosen expert
    combine = dispatch * gate[:, None, None]
    # GShard aux loss: E * sum_e (fraction routed to e) * (mean prob of e)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(onehot, axis=0)
    aux = num_experts * jnp.sum(me * ce)
    return dispatch, combine, aux


def moe_layer_ref(x, wg, w1, b1, w2, b2, capacity: int):
    """Full MoE layer oracle: route -> dispatch -> grouped FFN -> combine.

    x: (t, h).  Returns (y (t, h), aux_loss).
    """
    E = wg.shape[1]
    probs, top1 = router_ref(x, wg)
    dispatch, combine, aux = make_dispatch_ref(probs, top1, E, capacity)
    xd = jnp.einsum("tec,th->ech", dispatch, x)
    yd = moe_ffn_ref(xd, w1, b1, w2, b2)
    y = jnp.einsum("tec,ech->th", combine, yd)
    return y, aux
