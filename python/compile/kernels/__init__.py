# L1: Pallas kernels for the paper's compute hot-spot.
from . import dense_ffn, gating, moe_ffn, ref  # noqa: F401
