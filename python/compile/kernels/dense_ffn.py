"""Dense FFN Pallas kernel — the non-MoE (tensor-parallel baseline) block.

Same GEMM -> GeLU -> GEMM structure as one expert of the grouped kernel, but
over the full token stream. Used by the dense transformer blocks of the
backbone and as the monolithic side of the §3.3.2 serialization benchmark
(one big GEMM vs E small ones).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .moe_ffn import _gelu


def _dense_ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    hidden = _gelu(
        jnp.dot(x_ref[...], w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...]
    )
    out_ref[...] = (
        jnp.dot(hidden, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...]
    )


def _dense_ffn_call(block_t, x, w1, b1, w2, b2):
    t, h = x.shape
    f = w1.shape[1]
    assert t % block_t == 0, f"tokens {t} not divisible by block_t {block_t}"
    return pl.pallas_call(
        _dense_ffn_kernel,
        grid=(t // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, h), lambda i: (i, 0)),
            pl.BlockSpec((h, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h), jnp.float32),
        interpret=True,
    )(x, w1, b1, w2, b2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dense_ffn_vjp(block_t, x, w1, b1, w2, b2):
    return _dense_ffn_call(block_t, x, w1, b1, w2, b2)


def _dense_ffn_vjp_fwd(block_t, x, w1, b1, w2, b2):
    return _dense_ffn_call(block_t, x, w1, b1, w2, b2), (x, w1, b1, w2)


def _dense_ffn_vjp_bwd(block_t, res, dy):
    """Recompute-based FFN backward (jnp einsums; single expert, so the
    grouped pallas backward kernel would be pure overhead here)."""
    from .moe_ffn import _gelu_grad

    x, w1, b1, w2 = res
    pre = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1
    hidden = _gelu(pre)
    dhidden = jnp.dot(dy, w2.T, preferred_element_type=jnp.float32)
    dpre = dhidden * _gelu_grad(pre)
    dx = jnp.dot(dpre, w1.T, preferred_element_type=jnp.float32)
    dw1 = jnp.dot(x.T, dpre, preferred_element_type=jnp.float32)
    db1 = jnp.sum(dpre, axis=0)
    dw2 = jnp.dot(hidden.T, dy, preferred_element_type=jnp.float32)
    db2 = jnp.sum(dy, axis=0)
    return dx, dw1, db1, dw2, db2


_dense_ffn_vjp.defvjp(_dense_ffn_vjp_fwd, _dense_ffn_vjp_bwd)


def dense_ffn(x, w1, b1, w2, b2, *, block_t: int | None = None):
    """Dense FFN: (t, h) -> (t, h) with w1 (h, f), w2 (f, h). Differentiable."""
    if block_t is None:
        block_t = min(x.shape[0], 128)
    return _dense_ffn_vjp(block_t, x, w1, b1, w2, b2)
