"""Grouped expert FFN Pallas kernel — the PPMoE compute hot spot (L1).

The paper's per-device MoE work is a *serial loop over N local experts*,
each a GEMM -> GeLU -> GEMM FFN over that expert's token slice (§3.3.2).
On TPU we express the loop as a Pallas grid dimension instead: the grid is
(E, C/blk_c) and BlockSpec streams one (blk_c, h) token tile plus the
expert's (h, f)/(f, h) weight slabs HBM->VMEM per step. Both GEMMs target
the MXU with f32 accumulation (`preferred_element_type`).

Hardware adaptation (EXPERIMENTS.md §Serialization): the paper's claim that "serially
processing a few small tensors is nearly the same as one big tensor"
(footnote 6) maps to the fact that a grid over experts re-uses the same
systolic-array schedule per step — per-expert weight slabs are the only
extra HBM traffic versus one monolithic GEMM.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO with identical semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(x):
    # tanh-approx GeLU; keep in sync with ref.gelu.
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def _gelu_grad(x):
    """d/dx of the tanh-approx GeLU (used by the backward kernel)."""
    c = 0.7978845608028654
    t = jnp.tanh(c * (x + 0.044715 * x**3))
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * c * (1.0 + 3 * 0.044715 * x * x)


def _moe_ffn_kernel(xd_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    """One grid step: one expert e, one capacity tile c.

    VMEM working set: (blk_c, h) + (h, f) + (f,) + (f, h) + (h,) + (blk_c, h).
    """
    x = xd_ref[0]  # (blk_c, h)
    w1 = w1_ref[0]  # (h, f)
    b1 = b1_ref[0]  # (f,)
    w2 = w2_ref[0]  # (f, h)
    b2 = b2_ref[0]  # (h,)
    hidden = _gelu(jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1)
    out_ref[0] = jnp.dot(hidden, w2, preferred_element_type=jnp.float32) + b2


def _moe_ffn_fwd_call(block_c, xd, w1, b1, w2, b2):
    E, C, h = xd.shape
    f = w1.shape[2]
    assert C % block_c == 0, f"capacity {C} not divisible by block_c {block_c}"
    grid = (E, C // block_c)
    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, h), lambda e, c: (e, c, 0)),
            pl.BlockSpec((1, h, f), lambda e, c: (e, 0, 0)),
            pl.BlockSpec((1, f), lambda e, c: (e, 0)),
            pl.BlockSpec((1, f, h), lambda e, c: (e, 0, 0)),
            pl.BlockSpec((1, h), lambda e, c: (e, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, h), lambda e, c: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, h), jnp.float32),
        interpret=True,
    )(xd, w1, b1, w2, b2)


def _moe_ffn_bwd_kernel(xd_ref, w1_ref, b1_ref, w2_ref, dy_ref,
                        dxd_ref, dw1_ref, db1_ref, dw2_ref, db2_ref):
    """Backward for one expert (grid over E; full capacity slab per step).

    Recomputes the hidden activation, then the five cotangents. Weight grads
    accumulate over the whole capacity slab in one step, so no cross-step
    reduction state is needed.
    """
    x = xd_ref[0]   # (C, h)
    w1 = w1_ref[0]  # (h, f)
    b1 = b1_ref[0]  # (f,)
    w2 = w2_ref[0]  # (f, h)
    dy = dy_ref[0]  # (C, h)
    pre = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1
    hidden = _gelu(pre)
    dhidden = jnp.dot(dy, w2.T, preferred_element_type=jnp.float32)
    dpre = dhidden * _gelu_grad(pre)
    dxd_ref[0] = jnp.dot(dpre, w1.T, preferred_element_type=jnp.float32)
    dw1_ref[0] = jnp.dot(x.T, dpre, preferred_element_type=jnp.float32)
    db1_ref[0] = jnp.sum(dpre, axis=0)
    dw2_ref[0] = jnp.dot(hidden.T, dy, preferred_element_type=jnp.float32)
    db2_ref[0] = jnp.sum(dy, axis=0)


def _moe_ffn_bwd_call(xd, w1, b1, w2, dy):
    E, C, h = xd.shape
    f = w1.shape[2]
    return pl.pallas_call(
        _moe_ffn_bwd_kernel,
        grid=(E,),
        in_specs=[
            pl.BlockSpec((1, C, h), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, h, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, f), lambda e: (e, 0)),
            pl.BlockSpec((1, f, h), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, C, h), lambda e: (e, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, h), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, h, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, f), lambda e: (e, 0)),
            pl.BlockSpec((1, f, h), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, h), lambda e: (e, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, C, h), jnp.float32),
            jax.ShapeDtypeStruct((E, h, f), jnp.float32),
            jax.ShapeDtypeStruct((E, f), jnp.float32),
            jax.ShapeDtypeStruct((E, f, h), jnp.float32),
            jax.ShapeDtypeStruct((E, h), jnp.float32),
        ],
        interpret=True,
    )(xd, w1, b1, w2, dy)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _moe_ffn_vjp(block_c, xd, w1, b1, w2, b2):
    return _moe_ffn_fwd_call(block_c, xd, w1, b1, w2, b2)


def _moe_ffn_vjp_fwd(block_c, xd, w1, b1, w2, b2):
    return _moe_ffn_fwd_call(block_c, xd, w1, b1, w2, b2), (xd, w1, b1, w2)


def _moe_ffn_vjp_bwd(block_c, res, dy):
    xd, w1, b1, w2 = res
    dxd, dw1, db1, dw2, db2 = _moe_ffn_bwd_call(xd, w1, b1, w2, dy)
    return dxd, dw1, db1, dw2, db2


_moe_ffn_vjp.defvjp(_moe_ffn_vjp_fwd, _moe_ffn_vjp_bwd)


def moe_ffn(xd, w1, b1, w2, b2, *, block_c: int | None = None):
    """Grouped expert FFN: (E, C, h) -> (E, C, h). Differentiable.

    xd: dispatched tokens (E, C, h); w1: (E, h, f); b1: (E, f);
    w2: (E, f, h); b2: (E, h). block_c tiles the capacity dimension
    (must divide C; defaults to min(C, 128)). Forward and backward are both
    Pallas kernels (backward recomputes the hidden activation per expert).
    """
    C = xd.shape[1]
    if block_c is None:
        block_c = min(C, 128)
    return _moe_ffn_vjp(block_c, xd, w1, b1, w2, b2)


def vmem_bytes(block_c: int, h: int, f: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (for DESIGN §Perf)."""
    tiles = block_c * h * 2 + h * f + f + f * h + h
    return tiles * dtype_bytes


def mxu_flops_per_step(block_c: int, h: int, f: int) -> int:
    """MACs*2 issued to the MXU per grid step."""
    return 2 * block_c * h * f * 2
