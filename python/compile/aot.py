"""AOT export: lower every artifact to HLO *text* + manifest + param bins.

Python runs exactly once (`make artifacts`); the Rust binary is
self-contained afterwards. Interchange format is HLO text, NOT
`.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids
that the image's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs under --out-dir (default ../artifacts):
  <name>.hlo.txt          one per artifact
  manifest.json           configs, artifact arg/result shapes, param layouts
  params/stage<i>.bin     initial parameters, raw little-endian f32,
                          concatenated in manifest order

Usage: python -m compile.aot [--out-dir DIR] [--config tiny|small|medium|...]
                             [--tp N] [--seed S] [--virtual V] [--no-full]
                             [--tp-pipeline] [--top-k K] [--capacity-factor CF]

`--virtual V` exports each stage as V non-contiguous chunks (interleaved
virtual-stage 1F1B): per-(stage, chunk) fwd/bwd artifacts plus a `chunks`
manifest table; see docs/schedules.md.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, stages
from .model import ModelConfig

# Named configs. `tiny` keeps CI fast; `small` is the default example scale;
# `medium` approaches the per-stage size a real run would use on this CPU.
CONFIGS: dict[str, ModelConfig] = {
    "tiny": ModelConfig(vocab=256, hidden=64, ffn=256, layers=2, heads=4,
                        experts=4, seq=32, micro_batch=2, stages=2,
                        block_c=32, block_t=64),
    # tiny widths but 8 layers: divisible into 2 stages x {1, 2, 4} virtual
    # chunks — the interleaved-1F1B test target (`make artifacts-tiny-v4`)
    "tiny-deep": ModelConfig(vocab=256, hidden=64, ffn=256, layers=8, heads=4,
                             experts=4, seq=32, micro_batch=2, stages=2,
                             block_c=32, block_t=64),
    "small": ModelConfig(vocab=512, hidden=128, ffn=512, layers=4, heads=4,
                         experts=8, seq=64, micro_batch=4, stages=2,
                         block_c=64, block_t=128),
    "medium": ModelConfig(vocab=2048, hidden=256, ffn=1024, layers=8, heads=8,
                          experts=16, seq=128, micro_batch=4, stages=4,
                          block_c=128, block_t=256),
    # dense backbone of `small` (Fig. 5 comparison: PPMoE vs its backbone)
    "small-dense": ModelConfig(vocab=512, hidden=128, ffn=512, layers=4,
                               heads=4, experts=2, moe_every=0, seq=64,
                               micro_batch=4, stages=2, block_c=64,
                               block_t=128),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dt).name]


def _spec(arr) -> dict:
    return {"shape": list(arr.shape), "dtype": _dtype_tag(arr.dtype)}


def lower_artifact(name: str, fn, example_args, out_dir: str,
                   input_names: list[str] | None = None) -> dict:
    """Lower fn(*example_args), write HLO text, return manifest entry."""
    # keep_unused=True: jit otherwise DCEs arguments the computation doesn't
    # read (e.g. a bias that cancels out of a backward), which would break
    # the positional input contract the Rust runtime relies on.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *example_args)
    outs = jax.tree_util.tree_leaves(out_shapes)
    entry = {
        "file": fname,
        "inputs": [
            {"name": (input_names[i] if input_names else f"arg{i}"), **_spec(a)}
            for i, a in enumerate(example_args)
        ],
        "outputs": [_spec(o) for o in outs],
    }
    print(f"  {name}: {len(text)} chars, {len(example_args)} in / {len(outs)} out")
    return entry


def save_stage_params(out_dir: str, stage: int, names: list[str], leaves,
                      bin_name: str | None = None) -> dict:
    """Raw LE f32 concat + layout. Returns the manifest 'stages' entry."""
    os.makedirs(os.path.join(out_dir, "params"), exist_ok=True)
    binfile = f"params/{bin_name or f'stage{stage}'}.bin"
    layout, offset = [], 0
    with open(os.path.join(out_dir, binfile), "wb") as f:
        for name, leaf in zip(names, leaves):
            arr = np.asarray(leaf, dtype=np.float32)
            f.write(arr.tobytes())
            layout.append({
                "name": name, "shape": list(arr.shape),
                "offset": offset, "numel": int(arr.size),
            })
            offset += arr.size * 4
    return {"bin": binfile, "params": layout, "total_bytes": offset}


def export_tp_exec(cfg, out_dir: str, tp: int,
                   chunk_params, manifest: dict) -> None:
    """Additive tp-pipeline export: per-rank expert-sharded segment
    artifacts + the manifest ``tp_exec`` table the live trainer's `--tp n`
    executes (see stages.tp_chunk_plan). Parameters are SLICES of the same
    initialization the monolithic artifacts ship, written as per-(stage,
    rank) bins, each layout entry tagged with its gradient class."""
    arts = manifest["artifacts"]
    S, V = cfg.stages, cfg.virtual_stages
    tp_exec: dict = {"tp": tp, "ranks": []}
    print(f"[aot] tp-pipeline export: {tp} ranks")
    for r in range(tp):
        rank_stages = []
        for s in range(S):
            names, leaves, grads, chunk_meta = [], [], [], []
            for c in range(V):
                plan = stages.tp_chunk_plan(cfg, s, c)
                v_idx = c * S + s
                seg_meta = []
                for k, seg in enumerate(plan):
                    first = k == 0
                    pdict = stages.tp_segment_params(
                        chunk_params[s][c], seg, cfg, r, tp, first, v_idx)
                    pn, pl, _ = stages.flatten_params(pdict)
                    names += [f"chunk{c}.seg{k}.{n}" for n in pn]
                    leaves += pl
                    grads += stages.tp_seg_grad_class(seg, pn)
                    base = f"stage{s}_chunk{c}_seg{k}"
                    tokens_in = (first and s == 0 and c == 0
                                 and not seg["post_moe"])
                    if seg["kind"] == "moe":
                        fwd = f"{base}_moe_rank{r}of{tp}_fwd"
                        bwd = f"{base}_moe_rank{r}of{tp}_bwd"
                        fn, ex, _ = stages.make_tp_moe_seg_fwd(
                            cfg, r, tp, pdict)
                        arts[fwd] = lower_artifact(
                            fwd, fn, ex, out_dir, [*pn, "hgt"])
                        fn, ex, _ = stages.make_tp_moe_seg_bwd(
                            cfg, r, tp, pdict)
                        arts[bwd] = lower_artifact(
                            bwd, fn, ex, out_dir, [*pn, "hgt", "dy", "daux"])
                        seg_meta.append({
                            "kind": "moe", "fwd": fwd, "bwd": bwd,
                            "params": len(pn), "xy": False, "pair": False,
                            "aux": True, "dx": True,
                        })
                        continue
                    xy = seg["post_moe"]
                    pair = seg["pre_moe"] is not None
                    xs = ["x", "y"] if xy else ["x"]
                    if seg["kind"] == "losstail":
                        bwd = f"{base}_losstail"
                        if r == 0:  # replicated: shared across ranks
                            fn, ex, _ = stages.make_tp_losstail(
                                cfg, s, c, seg, pdict, first)
                            arts[bwd] = lower_artifact(
                                bwd, fn, ex, out_dir,
                                [*pn, *xs, "targets", "aux_in"])
                        seg_meta.append({
                            "kind": "losstail", "fwd": None, "bwd": bwd,
                            "params": len(pn), "xy": xy, "pair": False,
                            "aux": False, "dx": not tokens_in,
                        })
                        continue
                    fwd, bwd = f"{base}_fwd", f"{base}_bwd"
                    if r == 0:  # replicated: shared across ranks
                        cts = ["dx2", "dhgt"] if pair else ["dh"]
                        fn, ex, _ = stages.make_tp_glue_fwd(
                            cfg, s, c, seg, pdict, first)
                        arts[fwd] = lower_artifact(fwd, fn, ex, out_dir,
                                                   [*pn, *xs])
                        fn, ex, _ = stages.make_tp_glue_bwd(
                            cfg, s, c, seg, pdict, first)
                        arts[bwd] = lower_artifact(bwd, fn, ex, out_dir,
                                                   [*pn, *xs, *cts])
                    seg_meta.append({
                        "kind": "glue", "fwd": fwd, "bwd": bwd,
                        "params": len(pn), "xy": xy, "pair": pair,
                        "aux": False, "dx": not tokens_in,
                    })
                chunk_meta.append(seg_meta)
            entry = save_stage_params(out_dir, s, names, leaves,
                                      bin_name=f"stage{s}.tp{r}of{tp}")
            for spec, g in zip(entry["params"], grads):
                spec["grad"] = g
            entry["chunks"] = chunk_meta
            rank_stages.append(entry)
        tp_exec["ranks"].append(rank_stages)
    manifest["tp_exec"] = tp_exec


def export(cfg_name: str, out_dir: str, tp: int, seed: int,
           include_full: bool, virtual: int = 1,
           tp_pipeline: bool = False, top_k: int = 0,
           capacity_factor: float | None = None) -> None:
    cfg = CONFIGS[cfg_name]
    if virtual != 1:
        cfg = dataclasses.replace(cfg, virtual_stages=virtual)
    if top_k > 0:
        cfg = dataclasses.replace(cfg, top_k=top_k)
    if capacity_factor is not None:
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    # validate() raises loudly on an unroutable schedule (top_k > experts,
    # capacity_factor < 1/experts) BEFORE any artifact is written
    cfg.validate()
    os.makedirs(out_dir, exist_ok=True)
    key = jax.random.PRNGKey(seed)

    manifest: dict = {
        "config_name": cfg_name,
        "config": dataclasses.asdict(cfg),
        "tp": tp,
        "stages": [],
        "artifacts": {},
    }
    arts = manifest["artifacts"]
    v = cfg.virtual_stages

    print(f"[aot] config={cfg_name} stages={cfg.stages} "
          f"virtual={v} tp={tp} top_k={cfg.top_k} "
          f"capacity={cfg.capacity} (cf={cfg.capacity_factor})")
    if v == 1:
        # plain pipeline: per-stage artifacts, no "chunks" section (the
        # Rust manifest synthesizes the single-chunk view)
        all_params = model.init_all(key, cfg)
        for s in range(cfg.stages):
            names, leaves, _ = stages.flatten_params(all_params[s])
            manifest["stages"].append(
                save_stage_params(out_dir, s, names, leaves))

            fn, ex, pnames = stages.make_stage_fwd(cfg, s, all_params[s])
            arts[f"stage{s}_fwd"] = lower_artifact(
                f"stage{s}_fwd", fn, ex, out_dir, [*pnames, "x"])

            fn, ex, pnames = stages.make_stage_bwd(cfg, s, all_params[s])
            arts[f"stage{s}_bwd"] = lower_artifact(
                f"stage{s}_bwd", fn, ex, out_dir, [*pnames, "x", "dy", "daux"])

        s_last = cfg.stages - 1
        last_params = all_params[s_last]
        chunk_params = [[p] for p in all_params]
    else:
        # interleaved pipeline: per-(stage, chunk) artifacts plus the
        # manifest "chunks" table; each stage's bin concatenates its
        # chunks' params in chunk order, so chunk c addresses a contiguous
        # sub-slice of the stage params (manifest.chunk_param_range)
        chunk_params = model.init_all_chunks(key, cfg)
        manifest["chunks"] = []
        for s in range(cfg.stages):
            names, leaves, chunk_meta = [], [], []
            for c in range(v):
                cn, cl, _ = stages.flatten_params(chunk_params[s][c])
                names += [f"chunk{c}.{n}" for n in cn]
                leaves += cl
                is_loss = s == cfg.stages - 1 and c == v - 1
                if is_loss:
                    chunk_meta.append(
                        {"fwd": None, "bwd": "lossgrad", "params": len(cn)})
                else:
                    fwd_name = f"stage{s}_chunk{c}_fwd"
                    bwd_name = f"stage{s}_chunk{c}_bwd"
                    chunk_meta.append(
                        {"fwd": fwd_name, "bwd": bwd_name, "params": len(cn)})
                    fn, ex, pnames = stages.make_chunk_fwd(
                        cfg, s, c, chunk_params[s][c])
                    arts[fwd_name] = lower_artifact(
                        fwd_name, fn, ex, out_dir, [*pnames, "x"])
                    fn, ex, pnames = stages.make_chunk_bwd(
                        cfg, s, c, chunk_params[s][c])
                    arts[bwd_name] = lower_artifact(
                        bwd_name, fn, ex, out_dir,
                        [*pnames, "x", "dy", "daux"])
            manifest["stages"].append(
                save_stage_params(out_dir, s, names, leaves))
            manifest["chunks"].append(chunk_meta)
        last_params = chunk_params[-1][-1]

    fn, ex, pnames = stages.make_last_stage_lossgrad(cfg, last_params)
    arts["lossgrad"] = lower_artifact(
        "lossgrad", fn, ex, out_dir, [*pnames, "x", "targets", "aux_in"])

    fn, ex, pnames = stages.make_last_stage_loss(cfg, last_params)
    arts["loss_eval"] = lower_artifact(
        "loss_eval", fn, ex, out_dir, [*pnames, "x", "targets", "aux_in"])

    if include_full:
        if v == 1:
            fn, ex, pnames = stages.make_full_lossgrad(cfg, all_params)
        else:
            fn, ex, pnames = stages.make_full_lossgrad_chunks(cfg, chunk_params)
        arts["full_lossgrad"] = lower_artifact(
            "full_lossgrad", fn, ex, out_dir, [*pnames, "tokens", "targets"])

    # TP x EP rank artifacts + the monolithic reference (§3.3.2-3.3.4)
    for r in range(tp):
        fn, ex = stages.make_moe_rank(cfg, r, tp)
        arts[f"moe_rank{r}of{tp}"] = lower_artifact(
            f"moe_rank{r}of{tp}", fn, ex, out_dir,
            ["x", "wg", "w1", "b1", "w2", "b2"])
    fn, ex = stages.make_moe_single(cfg)
    arts["moe_single"] = lower_artifact(
        "moe_single", fn, ex, out_dir, ["x", "wg", "w1", "b1", "w2", "b2"])

    # §3.3.2 serialization experiment: one big FFN vs E grouped small ones
    fn, ex = stages.make_ffn_mono(cfg)
    arts["ffn_mono"] = lower_artifact(
        "ffn_mono", fn, ex, out_dir, ["x", "w1", "b1", "w2", "b2"])
    fn, ex = stages.make_ffn_grouped_eq(cfg)
    arts["ffn_grouped"] = lower_artifact(
        "ffn_grouped", fn, ex, out_dir, ["xd", "w1", "b1", "w2", "b2"])

    # live trainer tp-pipeline scheme (`--tp n`): per-rank expert-sharded
    # segment artifacts + the manifest tp_exec table; additive — the
    # monolithic artifacts above stay, so tp = 1 runs are untouched
    if tp_pipeline and tp > 1:
        export_tp_exec(cfg, out_dir, tp, chunk_params, manifest)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {out_dir}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", dest="out_compat", default=None,
                    help="(Makefile compat) path of the primary HLO file; "
                         "its directory becomes --out-dir")
    ap.add_argument("--config", default="small", choices=sorted(CONFIGS))
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--virtual", type=int, default=1,
                    help="interleaved 1F1B: virtual chunks per pipeline "
                         "stage (layers must divide stages*virtual)")
    ap.add_argument("--no-full", action="store_true",
                    help="skip the whole-model lossgrad artifact")
    ap.add_argument("--tp-pipeline", action="store_true",
                    help="also export per-rank expert-sharded SEGMENT "
                         "artifacts + the manifest tp_exec table, enabling "
                         "the live trainer's --tp n (requires --tp > 1)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="gating schedule: dispatch each token to its k "
                         "best experts, gate weights renormalized over the "
                         "winners (0 = keep the config's default, top-1). "
                         "Must be <= the config's expert count.")
    ap.add_argument("--capacity-factor", type=float, default=None,
                    help="expert capacity = cf*k*tokens/E (0 = uncapped); "
                         "overrides the config's default. Must be 0 or "
                         ">= 1/experts.")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out_compat:
        out_dir = os.path.dirname(args.out_compat) or "."
    export(args.config, out_dir, args.tp, args.seed, not args.no_full,
           virtual=args.virtual, tp_pipeline=args.tp_pipeline,
           top_k=args.top_k, capacity_factor=args.capacity_factor)
    if args.out_compat:
        # Makefile freshness stamp: alias the first stage/chunk artifact
        src = os.path.join(out_dir, "stage0_fwd.hlo.txt")
        if not os.path.exists(src):
            src = os.path.join(out_dir, "stage0_chunk0_fwd.hlo.txt")
        with open(src) as fi, open(args.out_compat, "w") as fo:
            fo.write(fi.read())


if __name__ == "__main__":
    main()
